"""Real (non-simulated) edge executors: jitted forwards for the models in a
ParamStore, driving the same Scheduler policy objects as the simulator.

Two serve paths share the policy layer:

* :class:`EdgeExecutor` — the straightforward per-request loop (one forward
  per request, synchronous DMA).  Kept as the baseline the benchmarks compare
  against.
* :class:`MergeAwareEngine` — the merge-aware hot path (DESIGN.md S1):
  cached materialisation (``ParamStore.materialize_cached``), shared-prefix
  batched execution (one stem run per micro-batch for models whose prefix
  weights are bound to the same store keys), suffix-bank fan-out (DESIGN.md
  S2: congruent private heads stacked into one leading-axis weight bank and
  executed in ONE dispatch per micro-batch), deadline-sorted micro-batches,
  async DMA prefetch (the next group's incremental load overlaps the
  current group's compute instead of stalling the accelerator), and hot
  MergePlan swap (``apply_plan``: a cloud-shipped plan lands on the live
  engine with one epoch bump and no dropped requests — DESIGN.md P1) plus
  the symmetric drift ``revert`` (a breached model drops back to its
  original private weights under load, queued requests surviving, driven by
  ``serving/lifecycle.py`` — DESIGN.md L1).

The DMA delay is modelled (the host has no PCIe-attached accelerator) but
residency, eviction and merging-aware incremental loads are all real key-set
operations.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.store import ParamStore
from repro.serving.scheduler import Instance, Scheduler
from repro.serving.workload import bucket_for, deadline_microbatches, pad_stack


def base_model_id(instance_id: str) -> str:
    """ParamStore bindings key for an instance id: feed instances are named
    ``<model>#<k>`` (``workload.build_instances``); bare model ids pass
    through unchanged."""
    return instance_id.split("#", 1)[0]


@dataclasses.dataclass
class Request:
    instance_id: str
    payload: Any
    arrival_s: float
    deadline_s: float
    meta: Any = None  # opaque caller tag (e.g. (camera, frame_index))


class PlanApplyError(RuntimeError):
    """A hot plan swap failed mid-flight.  The engine guarantees the store
    was rolled back to its pre-swap buffers/bindings with exactly ONE epoch
    bump and no queued request dropped; callers (LifecycleController) keep
    serving the prior plan."""


def drop_expired(queues: dict, now: float) -> int:
    """Drop queue heads whose deadline has passed; returns the count.  The
    ONE expiry helper both executors share — expired requests are counted
    (``dropped_expired``), never silently vanished, so shed-rate accounting
    in the ingestion monitors stays honest."""
    n = 0
    for q in queues.values():
        while q and now > q[0].deadline_s:
            q.popleft()
            n += 1
    return n


@dataclasses.dataclass
class Completion:
    request: Request
    result: Any
    finished_s: float

    @property
    def met_sla(self) -> bool:
        return self.finished_s <= self.request.deadline_s


class EdgeExecutor:
    """instances + forward fns + store -> serve loop over a request queue."""

    def __init__(
        self,
        store: ParamStore,
        instances: list,
        forward_fns: dict,  # instance_id -> callable(params, payload)
        capacity_bytes: int,
        costs: dict,
        dma_gbps: float = 16.0,
        simulate_dma: bool = True,
        idle_sleep_s: float = 2e-4,
        buckets: tuple = (1, 2, 4, 8),
        clock: Callable[[], float] = time.monotonic,
    ):
        self.store = store
        self.clock = clock  # injected so harness replays can freeze time
        self.scheduler = Scheduler(instances, capacity_bytes, costs)
        self.forward = {
            iid: jax.jit(fn) for iid, fn in forward_fns.items()
        }
        self.dma_gbps = dma_gbps
        self.simulate_dma = simulate_dma
        self.idle_sleep_s = idle_sleep_s
        self.buckets = tuple(sorted(buckets))
        self.queues = {i.instance_id: deque() for i in instances}
        self.completions: list = []
        self.skipped: int = 0
        self.dropped_expired: int = 0

    def submit(self, req: Request):
        self.queues[req.instance_id].append(req)

    def _drop_expired(self, now: float):
        n = drop_expired(self.queues, now)
        self.skipped += n
        self.dropped_expired += n

    def serve(self, horizon_s: float, batch: int = 1, warmup: Any = None,
              drain: bool = False) -> dict:
        """Round-robin over instances until the horizon (or, with
        ``drain=True``, until every queue is empty); returns stats.
        ``warmup`` payload (optional) compiles each instance's forward before
        the SLA clock starts — deployments always pre-compile.

        The requests taken from a queue run as ONE padded batch through the
        same :func:`pad_stack` bucket ladder the engine uses (a bounded set
        of jit shapes), so the baseline is honest about batching — what it
        lacks vs the engine is sharing, prefetch and the suffix bank, not
        the ability to stack frames."""
        order = [i.instance_id for i in self.scheduler.order]
        ladder = tuple(sorted({b for b in self.buckets if b <= batch} | {batch}))
        if warmup is not None:
            for iid in order:
                params = self.store.materialize_cached(base_model_id(iid))
                for b in ladder:
                    wb, _ = pad_stack([warmup] * b, b)
                    jax.block_until_ready(self.forward[iid](params, wb))
        t0 = self.clock()
        idx = 0
        empty_streak = 0
        while self.clock() - t0 < horizon_s:
            iid = order[idx % len(order)]
            idx += 1
            now = self.clock() - t0
            self._drop_expired(now)
            q = self.queues[iid]
            if not q:
                if drain and not any(self.queues.values()):
                    break
                empty_streak += 1
                if empty_streak >= len(order):
                    # every queue was empty for a full pass: yield instead of
                    # busy-spinning on the monotonic clock
                    time.sleep(self.idle_sleep_s)
                    empty_streak = 0
                continue
            empty_streak = 0
            r = self.scheduler.load(iid, batch)
            if self.simulate_dma and r["loaded_bytes"]:
                time.sleep(r["loaded_bytes"] / 1e9 / self.dma_gbps)
            params = self.store.materialize_cached(base_model_id(iid))
            taken = [q.popleft() for _ in range(min(batch, len(q)))]
            stacked, _ = pad_stack([req.payload for req in taken],
                                   bucket_for(len(taken), ladder))
            out = self.forward[iid](params, stacked)
            jax.block_until_ready(out)
            done = self.clock() - t0
            for j, req in enumerate(taken):
                self.completions.append(Completion(req, out[j], done))
        met = sum(1 for c in self.completions if c.met_sla)
        total = len(self.completions) + self.skipped
        return {
            "completed": len(self.completions),
            "met_sla": met,
            "skipped": self.skipped,
            "dropped_expired": self.dropped_expired,
            "sla_fraction": met / max(total, 1),
        }

    def serve_decode(self, requests: list, programs: list, max_len: int = 64,
                     horizon_s: float = 60.0, warmup: bool = True) -> dict:
        """Per-request decode baseline lane (DESIGN.md D1): each request gets
        its own contiguous KV cache (``DecodeSplit.init_cache``) and runs
        sequential ``step_unpaged`` calls to completion, one request at a
        time in EDF order — chunked prompt ingestion (ONE step over the whole
        prompt, so the denominator isn't a token-by-token strawman) followed
        by one single-token step per generated token.  Greedy argmax over
        the full padded vocab, same as the streaming engine.  Stats mirror
        the engine's ``tokens_decoded`` / ``steps`` / ``prompt_tokens`` so
        ``benchmarks/decode_serve.py`` compares like for like."""
        from repro.serving.decode import DecodeCompletion

        progs = {p.instance_id: p for p in programs}
        for req in requests:
            if progs[req.instance_id].decode is None:
                raise ValueError(f"{req.instance_id}: program has no decode "
                                 "surface (adapter lacks can_decode)")
        jitted: dict = {}

        def step_fn(dec):
            fn = jitted.get(id(dec.step_unpaged))
            if fn is None:
                fn = jitted[id(dec.step_unpaged)] = jax.jit(dec.step_unpaged)
            return fn

        import numpy as np

        order = sorted(requests, key=lambda r: (r.deadline_s, r.arrival_s))
        if warmup:  # pre-compile both shapes (prompt chunk + single token)
            seen = set()
            for req in order:
                dec = progs[req.instance_id].decode
                key = (id(dec), len(req.prompt))
                if key in seen:
                    continue
                seen.add(key)
                params = self.store.materialize_cached(
                    base_model_id(req.instance_id))
                step = step_fn(dec)
                cache = dec.init_cache(1, max_len)
                chunk = jnp.zeros((1, len(req.prompt)), jnp.int32)
                _, cache = step(params, cache, chunk)
                lg, _ = step(params, cache, jnp.zeros((1, 1), jnp.int32))
                jax.block_until_ready(lg)

        stats = {"steps": 0, "tokens_decoded": 0, "prompt_tokens": 0}
        completions: list = []
        t0 = self.clock()
        for req in order:
            if self.clock() - t0 > horizon_s:
                break
            iid = req.instance_id
            dec = progs[iid].decode
            r = self.scheduler.load(iid, 1)
            if self.simulate_dma and r["loaded_bytes"]:
                time.sleep(r["loaded_bytes"] / 1e9 / self.dma_gbps)
            params = self.store.materialize_cached(base_model_id(iid))
            step = step_fn(dec)
            cache = dec.init_cache(1, max_len)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache = step(params, cache, prompt)
            stats["steps"] += 1
            stats["prompt_tokens"] += len(req.prompt)
            out = [int(np.argmax(np.asarray(logits)[0, -1]))]
            stats["tokens_decoded"] += 1
            for _ in range(req.max_new_tokens - 1):
                tok = jnp.full((1, 1), out[-1], jnp.int32)
                logits, cache = step(params, cache, tok)
                stats["steps"] += 1
                out.append(int(np.argmax(np.asarray(logits)[0, 0])))
                stats["tokens_decoded"] += 1
            completions.append(
                DecodeCompletion(req, out, self.clock() - t0))
        self.decode_completions = completions
        elapsed = self.clock() - t0
        return {
            "completed": len(completions),
            "elapsed_s": elapsed,
            "tokens_per_s": stats["tokens_decoded"] / max(elapsed, 1e-9),
            **stats,
        }


# ---------------------------------------------------------------------------
# Merge-aware engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelProgram:
    """How the engine runs one instance.  ``forward`` is the whole model;
    when ``prefix``/``suffix`` are given the model is split so the engine can
    execute a merged stem once per micro-batch and fan out only the private
    head.  ``prefix_paths`` are the flat param paths the prefix reads — the
    engine checks against ``ParamStore.binding_signature`` that every path is
    bound to the same store key across candidate group members before it ever
    shares a prefix run.

    The suffix-bank tier (DESIGN.md S2): ``suffix_paths``/``suffix_signature``
    describe the private head's stacked-weight congruence and ``bank_suffix``
    (optional) is the adapter's fused fan-out ``(bank_params, feats) ->
    (N, B, ...)``.  Group members whose suffix signatures all match execute
    every private head in ONE dispatch instead of one per member."""

    instance_id: str
    model_id: str  # ParamStore bindings key
    forward: Callable  # (params, batched_x) -> batched_out
    prefix: Optional[Callable] = None  # (params, batched_x) -> batched_feats
    suffix: Optional[Callable] = None  # (params, batched_feats) -> batched_out
    prefix_paths: Optional[frozenset] = None
    suffix_paths: Optional[frozenset] = None
    suffix_signature: Optional[tuple] = None
    bank_suffix: Optional[Callable] = None  # (bank_params, feats) -> (N, ...)
    decode: Optional[Any] = None  # registry.DecodeSplit — streaming lane (D1)

    @classmethod
    def from_adapter(cls, adapter, instance_id: str,
                     model_id: Optional[str] = None, cfg=None,
                     split: bool = True) -> "ModelProgram":
        """Build a program from a registered ``MergeableAdapter`` — the one
        way models meet the engine (DESIGN.md P3); no more hand-wired
        closures per call site.  The adapter caches the cfg-bound forward
        and prefix/suffix callables, so every instance of one (adapter, cfg)
        hands the engine the SAME function objects and a shared-prefix group
        compiles once (see ``MergeAwareEngine._prefix_fn``)."""
        cfg = adapter.default_config() if cfg is None else cfg
        fwd = adapter.bound_forward(cfg)
        sp = adapter.split(cfg) if (split and adapter.can_split) else None
        ds = (adapter.decode_split(cfg)
              if (split and getattr(adapter, "can_decode", False)) else None)
        return cls(
            instance_id, model_id if model_id is not None else instance_id,
            forward=fwd,
            prefix=sp.prefix if sp else None,
            suffix=sp.suffix if sp else None,
            prefix_paths=sp.prefix_paths if sp else None,
            suffix_paths=sp.suffix_paths if sp else None,
            suffix_signature=sp.suffix_signature if sp else None,
            bank_suffix=sp.bank_suffix if sp else None,
            decode=ds,
        )


class AsyncDMA:
    """Models an async host->device copy engine: ``start`` begins a transfer
    (wall-clock timestamped), ``wait`` blocks only for the portion that did
    not overlap the compute issued in between.  With ``simulate=False`` the
    bookkeeping still runs (stall/hidden stats) but nothing sleeps — the path
    a real DMA queue would take."""

    def __init__(self, gbps: float, simulate: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.gbps = gbps
        self.simulate = simulate
        self.clock = clock
        self._inflight: dict = {}  # key -> (t_start, duration_s)
        self.stall_s = 0.0
        self.hidden_s = 0.0
        self.transfers = 0
        # per-shard transferred-bytes ledger (DESIGN.md S3): the sharded
        # engine attributes each load's bytes to the shards they land on
        self.bytes_by_shard: dict = {}

    def seconds_for(self, nbytes: int) -> float:
        return nbytes / 1e9 / self.gbps

    def account(self, shard_bytes: dict) -> None:
        """Credit a completed load's bytes to the shards they landed on
        (``Scheduler.load``'s ``loaded_bytes_by_shard``)."""
        for s, b in shard_bytes.items():
            if b:
                self.bytes_by_shard[s] = self.bytes_by_shard.get(s, 0) + b

    def start(self, key, nbytes: int) -> None:
        self._inflight[key] = (self.clock(), self.seconds_for(nbytes))
        if nbytes:
            self.transfers += 1

    def wait(self, key, nbytes: int) -> float:
        """Block until the transfer for ``key`` is done; returns the visible
        stall.  A key never started (cold miss) pays the full transfer."""
        entry = self._inflight.pop(key, None)
        now = self.clock()
        if entry is None:
            remaining = self.seconds_for(nbytes)
            if nbytes:
                self.transfers += 1
        else:
            t_start, dur = entry
            elapsed = now - t_start
            remaining = Scheduler.overlapped_load_ms(dur * 1e3, elapsed * 1e3) / 1e3
            self.hidden_s += min(dur, elapsed)
        self.stall_s += remaining
        if self.simulate and remaining > 0:
            time.sleep(remaining)
        return remaining


class MergeAwareEngine:
    """Batched, prefetching serve loop over a merged ParamStore.

    Execution plan (recomputed whenever the store's binding epoch moves):
    instances whose ``prefix_paths`` all bind to identical store keys form a
    *shared-prefix group* — their stems are one physical set of weights, so
    one prefix run serves every member's requests in a micro-batch; private
    suffixes fan out per instance.  Groups are visited in the scheduler's
    merging-aware round-robin order and the next group's incremental load is
    prefetched during the current group's compute.
    """

    def __init__(
        self,
        store: ParamStore,
        instances: list,
        programs: list,
        capacity_bytes: int,
        costs: dict,
        dma_gbps: float = 16.0,
        simulate_dma: bool = True,
        buckets: tuple = (1, 2, 4, 8),
        idle_sleep_s: float = 2e-4,
        suffix_bank: bool = True,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.store = store
        self.clock = clock  # shared with the DMA model below
        # with a mesh-sharded store the capacity budget is PER-SHARD and
        # admission checks every shard's slice (replicated trunk everywhere,
        # private suffixes on their home shard) — DESIGN.md S3
        self.scheduler = Scheduler(
            instances, capacity_bytes, costs,
            shard_fn=(store.resident_shards if store.n_shards > 1 else None),
            n_shards=store.n_shards,
        )
        self.programs = {p.instance_id: p for p in programs}
        missing = set(self.programs) ^ {i.instance_id for i in instances}
        if missing:
            raise ValueError(f"programs/instances mismatch: {missing}")
        self._fwd = {p.instance_id: jax.jit(p.forward) for p in programs}
        # prefixes compile lazily, cached per (callable identity, binding
        # signature): instances whose prefix weights are one physical buffer
        # set share ONE jitted prefix instead of tracing per instance
        self._prefix_compiled: dict = {}
        self._suffix = {p.instance_id: (jax.jit(p.suffix) if p.suffix else None)
                        for p in programs}
        self.dma = AsyncDMA(dma_gbps, simulate=simulate_dma, clock=clock)
        self.buckets = tuple(sorted(buckets))
        self.idle_sleep_s = idle_sleep_s
        self.suffix_bank = suffix_bank
        self.queues = {i.instance_id: deque() for i in instances}
        self.completions: list = []
        self.skipped = 0
        self.stats = {
            "prefix_runs": 0, "suffix_runs": 0, "forward_runs": 0,
            "microbatches": 0, "param_lookups": 0, "idle_sleeps": 0,
            "prefix_jits": 0, "suffix_dispatches": 0, "bank_hits": 0,
            "dropped_expired": 0,
        }
        self._groups: list = []
        self._groups_epoch = -1
        self._sigs: dict = {}  # iid -> binding signature, per groups epoch
        self._bankable: dict = {}  # group tuple -> bool, per groups epoch
        self._bank_compiled: dict = {}  # (callable, sig, N) -> jitted bank fn
        self._bank_sharded: dict = {}  # (callable, N, mesh, axis) -> shard_map'd fn

    # -- prefix compile cache (one trace per shared-prefix group) --------------

    @staticmethod
    def _callable_key(fn):
        """Trace-sharing identity of a prefix callable: closures produced
        from one body over the same captured values (e.g. per-instance
        lambdas from a list comprehension, or an adapter's cached split)
        compare equal, so a 4-member shared-prefix group maps onto ONE
        jitted prefix.  Falls back to object identity when the closure or
        defaults are unhashable."""
        code = getattr(fn, "__code__", None)
        if code is None:
            return id(fn)
        try:
            cells = tuple(id(c.cell_contents) for c in (fn.__closure__ or ()))
            key = (code, fn.__defaults__, cells)
            hash(key)
            return key
        except (TypeError, ValueError):
            return id(fn)

    def _binding_sig(self, iid: str) -> tuple:
        p = self.programs[iid]
        sig = self._sigs.get(iid)
        if sig is None:
            sig = self.store.binding_signature(p.model_id, p.prefix_paths)
            self._sigs[iid] = sig
        return sig

    def _prefix_fn(self, iid: str):
        """Jitted prefix for ``iid``.  Keyed by (callable, binding
        signature): group members bound to identical prefix keys reuse the
        same compiled entry — ``prefix_jits`` in the stats counts distinct
        compilations, so a 4-member group reports 1, not 4."""
        p = self.programs[iid]
        key = (self._callable_key(p.prefix), self._binding_sig(iid))
        fn = self._prefix_compiled.get(key)
        if fn is None:
            fn = jax.jit(p.prefix)
            self._prefix_compiled[key] = fn
            self.stats["prefix_jits"] += 1
        return fn

    # -- suffix bank (DESIGN.md S2) -------------------------------------------

    def _group_bankable(self, group: tuple) -> bool:
        """A shared group's fan-out runs as ONE banked dispatch iff every
        member's private head is congruent: same suffix paths and the same
        suffix signature (the adapter's shape/dtype fingerprint over the
        suffix leaves).  Cached per binding-epoch plan — an unmerge or plan
        swap re-evaluates eligibility on the next pass."""
        hit = self._bankable.get(group)
        if hit is None:
            progs = [self.programs[i] for i in group]
            sigs = {p.suffix_signature for p in progs}
            paths = {p.suffix_paths for p in progs}
            hit = (self.suffix_bank and len(group) > 1
                   and None not in sigs and len(sigs) == 1
                   and None not in paths and len(paths) == 1)
            self._bankable[group] = hit
        return hit

    def _bank_sharding_active(self, n_bank: int) -> bool:
        """Sharded bank dispatch is on iff the store carries a mesh placement
        with >1 shards on the bank axis AND the bank divides evenly over
        them (indivisible banks fall back to the replicated local dispatch —
        still bitwise, just not scaled)."""
        pl = self.store.placement
        return (pl is not None and self.store.n_shards > 1
                and n_bank % self.store.n_shards == 0)

    def maybe_shard_bank(self, fn, n_bank: int):
        """Wrap a bank fan-out callable ``(bank_params, feats) -> (N, ...)``
        in a ``shard_map`` over the placement's bank axis when sharding is
        active for ``n_bank`` (DESIGN.md S3): each device runs the SAME
        computation over its N/n_shards bank slice with replicated
        activations — the bank axis is batch-like, no contraction is split,
        so outputs stay bitwise identical to the unsharded dispatch while
        the grid (and Pallas BlockSpecs) become shard-local.  Cached per
        (callable, N, mesh, axis) so repeat callers (and the streaming
        decoder's jit cache) see a stable function identity."""
        if not self._bank_sharding_active(n_bank):
            return fn
        from repro.distributed.sharding import shard_bank_fn

        pl = self.store.placement
        key = (self._callable_key(fn), n_bank, pl.mesh, pl.bank_axis)
        wrapped = self._bank_sharded.get(key)
        if wrapped is None:
            wrapped = shard_bank_fn(fn, pl.mesh, pl.bank_axis)
            self._bank_sharded[key] = wrapped
        return wrapped

    def _bank_fn(self, group: list):
        """Jitted bank fan-out for a group: the adapter's fused
        ``bank_suffix`` when provided (``ops.bank_matmul`` grouped GEMM on
        TPU; the unrolled bitwise oracle in ``ref`` mode), else ``vmap`` of
        the member suffix over the stacked bank — the fallback for suffixes
        with no bank-aware callable (allclose-grade, still one dispatch).
        Under an active mesh placement the callable is shard_map'd over the
        bank axis first (:meth:`maybe_shard_bank`), so the dispatch scales
        with devices at unchanged output bits."""
        lead = self.programs[group[0]]
        sharded = self._bank_sharding_active(len(group))
        mesh = self.store.placement.mesh if sharded else None
        if lead.bank_suffix is not None:
            key = (self._callable_key(lead.bank_suffix),
                   lead.suffix_signature, len(group), mesh)
            base = lead.bank_suffix
        else:
            key = (self._callable_key(lead.suffix), "vmap",
                   lead.suffix_signature, len(group), mesh)
            base = None
        fn = self._bank_compiled.get(key)
        if fn is None:
            base_fn = (base if base is not None
                       else jax.vmap(lead.suffix, in_axes=(0, None)))
            fn = jax.jit(self.maybe_shard_bank(base_fn, len(group)))
            self._bank_compiled[key] = fn
        return fn

    def _bank_params(self, group: list):
        """Stacked suffix-bank pytree for the group, via the store's
        epoch-cached bank materialisation; ``bank_hits`` counts cache-served
        dispatches (one rebuild per group per binding epoch otherwise)."""
        self.stats["param_lookups"] += 1
        mids = tuple(self.programs[i].model_id for i in group)
        bid = ParamStore.bank_id(mids)
        before = self.store.materializations.get(bid, 0)
        tree = self.store.materialize_bank(
            mids, self.programs[group[0]].suffix_paths)
        if self.store.materializations.get(bid, 0) == before:
            self.stats["bank_hits"] += 1
        return tree

    # -- plan -----------------------------------------------------------------

    def prefix_groups(self) -> list:
        """Shared-prefix groups as lists of instance ids, ordered by first
        appearance in the merging-aware round-robin order.  Cached per store
        binding epoch: an unmerge splits a group on the next serve pass."""
        if self._groups_epoch == self.store.epoch:
            return self._groups
        self._sigs = {}  # epoch moved: binding signatures may have changed
        self._bankable = {}  # and group membership (bank eligibility) with them
        groups: list = []
        by_sig: dict = {}
        for inst in self.scheduler.order:
            iid = inst.instance_id
            p = self.programs[iid]
            if not (p.prefix and p.suffix and p.prefix_paths):
                groups.append([iid])
                continue
            sig = self._binding_sig(iid)
            if sig in by_sig:
                by_sig[sig].append(iid)
            else:
                by_sig[sig] = member = [iid]
                groups.append(member)
        # evict compiled prefixes whose binding signature died with the old
        # epoch — a long-lived engine replanning repeatedly must not pin
        # every historical jitted wrapper (and its executables) forever
        self._prefix_compiled = {
            k: v for k, v in self._prefix_compiled.items() if k[1] in by_sig
        }
        self._groups = groups
        self._groups_epoch = self.store.epoch
        return groups

    # -- hot plan swap / revert ------------------------------------------------

    def rebind_instances(self, key_bytes_fn=None) -> dict:
        """Rebuild scheduler instances from the store's CURRENT bindings
        (cost id and accuracy carried over per instance) and swap them in
        via ``Scheduler.rebind``, which preserves residency for surviving
        keys — the shared tail of ``apply_plan`` (P1 hot swap) and
        ``revert`` (L1 drift revert)."""
        from repro.utils.tree import leaf_bytes

        old = self.scheduler.instances
        kb_by_model: dict = {}  # store model -> {key: bytes}, computed once
        insts = []
        for iid, inst in old.items():
            mid = self.programs[iid].model_id
            if mid not in kb_by_model:
                kb_by_model[mid] = {
                    k: (key_bytes_fn(k, leaf_bytes(self.store.buffers[k]))
                        if key_bytes_fn else leaf_bytes(self.store.buffers[k]))
                    for k in self.store.keys_for(mid)
                }
            kb = kb_by_model[mid]
            insts.append(Instance(iid, inst.model_id, frozenset(kb), kb,
                                  inst.accuracy))
        return self.scheduler.rebind(insts)

    def apply_plan(self, plan, key_bytes_fn=None) -> dict:
        """Apply a MergePlan on the LIVE engine (DESIGN.md P1 hot swap):

        1. ``ParamStore.apply_plan`` stages every column rebind and commits
           with a *single* epoch bump — the prefix-group plan and every
           cached pytree invalidate exactly once;
        2. scheduler instances are rebuilt from the store's post-plan
           bindings (cost id and accuracy carried over per instance) and
           swapped in via ``Scheduler.rebind``, which preserves residency
           for keys the plan kept;
        3. queues are untouched — in-flight requests are served against the
           new bindings on the next pass (the serve loop re-reads
           ``prefix_groups()`` every iteration).

        The swap is ATOMIC under failure: ``ParamStore.apply_plan`` mutates
        buffers/bindings column by column and bumps the epoch only at the
        end, so an exception mid-flight (a poisoned payload, an injected
        fault) would otherwise strand a half-rebound store at the OLD epoch
        — every epoch-keyed cache would happily serve stale pytrees over
        partially mutated bindings.  The engine snapshots buffers + bindings
        up front; on any failure it restores both wholesale, settles the
        epoch at exactly ONE bump past the pre-swap value (consumers
        invalidate once, same as a successful swap), rebinds the scheduler
        from the restored bindings, and re-raises :class:`PlanApplyError`.
        Queues are never touched, so no queued request is dropped by a
        failed swap.
        """
        epoch0 = self.store.epoch
        buffers0 = dict(self.store.buffers)
        bindings0 = {m: dict(b) for m, b in self.store.bindings.items()}
        try:
            shared = self.store.apply_plan(plan)
        except Exception as exc:
            self.store.buffers.clear()
            self.store.buffers.update(buffers0)
            self.store.bindings.clear()
            self.store.bindings.update(bindings0)
            if self.store.epoch == epoch0:
                self.store.bump_epoch()  # one bump total for the failed swap
            else:
                self.store._cache.clear()  # already bumped: just invalidate
            self.rebind_instances(key_bytes_fn)
            raise PlanApplyError(f"plan swap failed and was rolled back: "
                                 f"{exc}") from exc
        rebind = self.rebind_instances(key_bytes_fn)
        return {
            "shared_keys": shared,
            "epoch_bumps": self.store.epoch - epoch0,
            "pending_requests": sum(len(q) for q in self.queues.values()),
            **rebind,
        }

    def revert(self, monitor, report, key_bytes_fn=None) -> dict:
        """Revert breached models to their original weights on the LIVE
        engine (§5.1 step 5, DESIGN.md L1) — the drift-side twin of
        ``apply_plan``, with the same no-drain guarantees:

        1. ``DriftMonitor.revert`` stages every breached model's private
           rebind and commits with a *single* epoch bump — cached pytrees,
           the prefix-group plan AND the suffix-bank materialisations all
           invalidate exactly once (``materialize_bank`` caches live in the
           same store cache ``bump_epoch`` clears);
        2. scheduler instances are rebuilt from the post-revert bindings;
           shared keys still referenced by surviving group members stay
           resident (``Scheduler.rebind``), so survivors' next loads are
           still free — only the reverted model pays its private bytes;
        3. queues are untouched: requests queued at breach time are served
           against the reverted bindings on the next pass, never dropped.
        """
        epoch0 = self.store.epoch
        pending = sum(len(q) for q in self.queues.values())
        monitor.revert(report)
        rebind = self.rebind_instances(key_bytes_fn)
        return {
            "reverted": sorted(report.reverted),
            "epoch_bumps": self.store.epoch - epoch0,
            "pending_requests": pending,
            **rebind,
        }

    # -- queue plumbing --------------------------------------------------------

    def submit(self, req: Request):
        self.queues[req.instance_id].append(req)

    def _drop_expired(self, now: float):
        n = drop_expired(self.queues, now)
        self.skipped += n
        self.stats["dropped_expired"] += n

    def _params(self, iid: str):
        self.stats["param_lookups"] += 1
        return self.store.materialize_cached(self.programs[iid].model_id)

    # -- execution -------------------------------------------------------------

    def _run_group(self, group: list, reqs: list, t0: float):
        """One group visit: deadline-sorted micro-batches over the union of
        the group's drained requests; shared groups run the prefix once per
        batch, singletons run the whole forward batched.

        Congruent shared groups additionally run the *suffix bank* stage
        (DESIGN.md S2): every member's private head executes over the whole
        micro-batch in ONE dispatch against the stacked bank weights — no
        per-member row gathers, no per-member suffix launches — and each
        completion scatters out of the (member, row) cell of the bank
        output.  The bank runs ALL of the group's heads, so it pays off
        exactly when a micro-batch fans out: batches whose rows belong to a
        single member keep the per-member path (one dispatch either way, no
        wasted head FLOPs under skewed traffic).  ``suffix_dispatches``
        counts device dispatches for suffix work (1 per banked micro-batch
        vs one per member otherwise); ``suffix_runs`` keeps counting
        logical member-head executions."""
        mbs = deadline_microbatches(reqs, self.buckets)
        shared = len(group) > 1
        bankable = shared and self._group_bankable(tuple(group))
        for mb in mbs:
            self.stats["microbatches"] += 1
            batch, n = pad_stack([r.payload for r in mb.requests], mb.bucket)
            banked = bankable and len(
                {r.instance_id for r in mb.requests}) > 1
            if banked:
                lead = group[0]
                feats = self._prefix_fn(lead)(self._params(lead), batch)
                self.stats["prefix_runs"] += 1
                bank_out = self._bank_fn(group)(self._bank_params(group), feats)
                self.stats["suffix_runs"] += len(group)
                self.stats["suffix_dispatches"] += 1
                jax.block_until_ready(bank_out)
                slot = {iid: i for i, iid in enumerate(group)}
                done = self.clock() - t0
                for j, r in enumerate(mb.requests):
                    self.completions.append(
                        Completion(r, bank_out[slot[r.instance_id], j], done))
                continue
            rows_by_iid: dict = {}
            for j, r in enumerate(mb.requests):
                rows_by_iid.setdefault(r.instance_id, []).append(j)
            if shared:
                lead = group[0]
                feats = self._prefix_fn(lead)(self._params(lead), batch)
                self.stats["prefix_runs"] += 1
                outs, pos = {}, {}
                for iid, idx in rows_by_iid.items():
                    if len(idx) == mb.bucket:
                        sub = feats  # whole batch belongs to this instance
                    else:
                        # fan out only this instance's rows, padded back onto
                        # the bucket ladder so suffix shapes stay bounded
                        sb = next(b for b in self.buckets if len(idx) <= b)
                        take = idx + [idx[-1]] * (sb - len(idx))
                        sub = feats[jnp.asarray(take)]
                    outs[iid] = self._suffix[iid](self._params(iid), sub)
                    pos[iid] = {g: k for k, g in enumerate(idx)}
                    self.stats["suffix_runs"] += 1
                    self.stats["suffix_dispatches"] += 1
            else:
                (iid,) = group
                outs = {iid: self._fwd[iid](self._params(iid), batch)}
                pos = {iid: {j: j for j in range(len(mb.requests))}}
                self.stats["forward_runs"] += 1
            for o in outs.values():
                jax.block_until_ready(o)
            done = self.clock() - t0
            for j, r in enumerate(mb.requests):
                row = pos[r.instance_id][j]
                self.completions.append(Completion(r, outs[r.instance_id][row], done))

    def _warmup(self, payload) -> None:
        """Pre-compile every (group, bucket) shape before the SLA clock
        starts — deployments always pre-compile.  ``payload`` follows the
        request-payload contract (a single frame, optionally with a leading
        batch-1 axis) and goes through the same :func:`pad_stack` as the
        serve path, so exactly the serving shapes are compiled."""
        for group in self.prefix_groups():
            banked = len(group) > 1 and self._group_bankable(tuple(group))
            for b in self.buckets:
                batch, _ = pad_stack([payload] * b, b)
                if len(group) > 1:
                    feats = self._prefix_fn(group[0])(self._params(group[0]), batch)
                    if banked:
                        # single-member micro-batches still take the
                        # per-member path, so compile both fan-outs
                        jax.block_until_ready(
                            self._bank_fn(group)(self._bank_params(group), feats))
                    for iid in group:
                        jax.block_until_ready(
                            self._suffix[iid](self._params(iid), feats))
                else:
                    (iid,) = group
                    jax.block_until_ready(self._fwd[iid](self._params(iid), batch))

    def serve_decode(self, requests: list, horizon_s: float = 60.0,
                     on_step: Optional[Callable] = None, **kw) -> dict:
        """Streaming decode lane (DESIGN.md D1): paged KV pool + continuous
        batching via ``serving.decode.StreamingDecoder`` — the shared trunk
        of a merged group advances every in-flight row ONE token per step in
        a single dispatch, private heads fan out through the suffix bank.
        ``**kw`` forwards pool/batching knobs (``page_size``, ``num_pages``,
        ``max_slots``, ``max_len``, ``record_logits``); ``on_step(decoder,
        step)`` fires after every engine step (the mid-decode hot-swap hook).
        The decoder is kept on ``last_decoder`` for verification
        (completions, pool accounting, recorded logits)."""
        from repro.serving.decode import StreamingDecoder

        dec = StreamingDecoder(self, **kw)
        self.last_decoder = dec
        return dec.run(requests, horizon_s=horizon_s, on_step=on_step)

    def serve(self, horizon_s: float, warmup: Any = None, drain: bool = True) -> dict:
        """Serve until the horizon (or until the queues are drained, with
        ``drain=True``).  Returns stats including cache/prefetch health."""
        if warmup is not None:
            self._warmup(warmup)
        # per-call accounting: every counter below is reported as the delta
        # over this serve() call (the instance-level counters keep cumulating)
        mat_before = dict(self.store.materializations)
        stats_before = dict(self.stats)
        done_before = len(self.completions)
        skipped_before = self.skipped
        stall_before, hidden_before = self.dma.stall_s, self.dma.hidden_s
        epoch_start = self.store.epoch
        t0 = self.clock()
        gi = 0
        empty_streak = 0
        while self.clock() - t0 < horizon_s:
            groups = self.prefix_groups()  # re-plan if an epoch moved
            now = self.clock() - t0
            self._drop_expired(now)
            if not any(self.queues.values()):
                if drain:
                    break
                self.stats["idle_sleeps"] += 1
                time.sleep(self.idle_sleep_s)
                continue
            group = groups[gi % len(groups)]
            nxt = groups[(gi + 1) % len(groups)]
            gi += 1
            reqs = []
            for iid in group:
                q = self.queues[iid]
                while q:
                    reqs.append(q.popleft())
            if not reqs:
                empty_streak += 1
                if empty_streak >= len(groups):
                    self.stats["idle_sleeps"] += 1
                    time.sleep(self.idle_sleep_s)
                    empty_streak = 0
                continue
            empty_streak = 0
            max_batch = min(len(reqs), self.buckets[-1])
            loaded = 0
            shard_bytes: dict = {}
            for iid in group:
                r = self.scheduler.load(iid, max_batch)
                loaded += r["loaded_bytes"]
                for s, b in r["loaded_bytes_by_shard"].items():
                    shard_bytes[s] = shard_bytes.get(s, 0) + b
            self.dma.wait(tuple(group), loaded)
            self.dma.account(shard_bytes)
            # prefetch the NEXT group's incremental bytes; the transfer's
            # clock runs while this group computes (§3.2 pipelining, made
            # real).  Sized by peek (pre-eviction estimate).
            if tuple(nxt) != tuple(group):
                pre = sum(self.scheduler.peek_load_bytes(iid) for iid in nxt)
                self.dma.start(tuple(nxt), pre)
            self._run_group(group, reqs, t0)
        new = self.completions[done_before:]
        met = sum(1 for c in new if c.met_sla)
        skipped = self.skipped - skipped_before
        total = len(new) + skipped
        lookups = self.stats["param_lookups"] - stats_before["param_lookups"]
        rebuilds = sum(self.store.materializations.get(m, 0) - mat_before.get(m, 0)
                       for m in self.store.materializations)
        last = max((c.finished_s for c in new), default=0.0)
        return {
            "completed": len(new),
            "met_sla": met,
            "skipped": skipped,
            "sla_fraction": met / max(total, 1),
            "elapsed_s": last,
            "requests_per_s": len(new) / max(last, 1e-9),
            "cache_hit_rate": 1.0 - rebuilds / max(lookups, 1),
            "materializations": rebuilds,
            "binding_epochs": self.store.epoch - epoch_start + 1,
            "dma_stall_s": self.dma.stall_s - stall_before,
            "dma_hidden_s": self.dma.hidden_s - hidden_before,
            "dma_bytes_by_shard": dict(self.dma.bytes_by_shard),
            # lifetime count (compiles usually happen in warmup, so the
            # per-call delta under-reports): distinct compiled prefixes —
            # a 4-member shared group contributes 1, not 4
            "prefix_jits_total": self.stats["prefix_jits"],
            **{k: v - stats_before[k] for k, v in self.stats.items()},
        }

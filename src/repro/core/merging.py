"""Joint retraining of a merging configuration (§5.3 "Accelerating
retraining").

Given a :class:`ParamStore` whose bindings already reflect the candidate
configuration (shared keys in place), jointly train every involved model
end-to-end: the loss is the mean of per-model losses, so gradients from all
models sum into shared buffers (store.py makes this automatic).

Adaptive behaviours from the paper:
* **early success** — once a model's accuracy is within ``es_threshold`` of
  its target, shrink the amount of data trained per epoch, inversely
  proportional to gap/lift;
* **early failure** — a model whose accuracy has not improved for
  ``ef_epochs`` consecutive epochs (while below target) is evicted from the
  attempt and reported to the planner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.store import ParamStore
from repro.core.validation import RegisteredModel, meets_targets, validate
from repro.train.optimizer import AdamW
from repro.utils.tree import unflatten_paths


@dataclasses.dataclass
class MergeResult:
    success: bool
    accuracies: dict
    failed_models: set
    epochs_used: int
    wall_time: float
    data_fraction_log: list


@dataclasses.dataclass
class MergeTrainer:
    optimizer: Any = None
    max_epochs: int = 10
    es_threshold: float = 0.02  # start shrinking data within 2% of target
    ef_epochs: int = 2
    min_delta: float = 1e-3  # minimum accuracy lift that counts as progress
    min_data_fraction: float = 0.25
    clock: Callable[[], float] = time.monotonic  # injected for replay tests

    def __post_init__(self):
        if self.optimizer is None:
            self.optimizer = AdamW(lr=3e-4)

    # -- jitted joint step ----------------------------------------------------

    def _build_step(self, store: ParamStore, models: list):
        bindings = {m.model_id: dict(store.bindings[m.model_id]) for m in models}
        by_id = {m.model_id: m for m in models}

        def materialize(mid, buffers):
            return unflatten_paths({p: buffers[k] for p, k in bindings[mid].items()})

        def joint_loss(buffers, batches):
            total = 0.0
            for mid in sorted(bindings.keys()):
                params = materialize(mid, buffers)
                total = total + by_id[mid].loss_fn(params, batches[mid])
            return total / len(bindings)

        opt = self.optimizer

        @jax.jit
        def step(buffers, opt_state, batches):
            loss, grads = jax.value_and_grad(joint_loss)(buffers, batches)
            buffers, opt_state = opt.update(grads, opt_state, buffers)
            return buffers, opt_state, loss

        return step, bindings

    # -- main loop -------------------------------------------------------------

    def train(self, store: ParamStore, models: list) -> MergeResult:
        t0 = self.clock()
        active = list(models)
        failed: set = set()
        data_frac = {m.model_id: 1.0 for m in models}
        frac_log: list = []
        stall = {m.model_id: 0 for m in models}
        prev_acc = validate(store, models)
        last_accs = dict(prev_acc)

        epoch = 0
        step = opt_state = None
        active_ids: tuple = ()
        while epoch < self.max_epochs and active:
            # (re)build the jitted step + optimizer state only when the set of
            # active models changes — Adam moments persist across epochs.
            if tuple(m.model_id for m in active) != active_ids:
                step, bindings = self._build_step(store, active)
                trainable = sorted({k for b in bindings.values() for k in b.values()})
                buffers = {k: store.buffers[k] for k in trainable}
                opt_state = self.optimizer.init(buffers)
                active_ids = tuple(m.model_id for m in active)

            # one epoch: per-model batch streams, truncated by data_frac.
            # Models with reduced data cycle their shortened stream; the
            # epoch shrinks only when EVERY model is in early-success.
            streams = {}
            for m in active:
                batches = list(m.train_batches(epoch))
                n = max(1, int(len(batches) * data_frac[m.model_id]))
                streams[m.model_id] = batches[:n]
            n_steps = max(len(s) for s in streams.values())
            for i in range(n_steps):
                batch_dict = {mid: streams[mid][i % len(streams[mid])] for mid in streams}
                buffers, opt_state, loss = step(buffers, opt_state, batch_dict)
            store.update_buffers(buffers)  # commit + invalidate cached pytrees
            epoch += 1

            accs = validate(store, active)
            last_accs.update(accs)
            frac_log.append(dict(data_frac))

            if meets_targets(accs, active):
                return MergeResult(True, last_accs, failed, epoch,
                                   self.clock() - t0, frac_log)

            # Early-failure is *relative*: a model stalls only if it made no
            # progress while other below-target models did (paper: "not
            # improving at the same pace as the rest").
            lifts = {m.model_id: accs[m.model_id] - prev_acc.get(m.model_id, 0.0)
                     for m in active}
            below = [m for m in active if accs[m.model_id] < m.absolute_target]
            others_progress = {
                m.model_id: any(
                    lifts[o.model_id] > self.min_delta
                    for o in below if o.model_id != m.model_id
                )
                for m in active
            }
            still_active = []
            for m in active:
                mid = m.model_id
                lift, gap = lifts[mid], m.absolute_target - accs[mid]
                if gap <= 0:
                    # met target: keep training (others may pull it down) but
                    # with minimal data.
                    data_frac[mid] = self.min_data_fraction
                    still_active.append(m)
                elif gap <= self.es_threshold:
                    # early success: data inversely proportional to gap/lift
                    ratio = gap / max(lift, 1e-4)
                    data_frac[mid] = float(
                        jnp.clip(ratio, self.min_data_fraction, 1.0)
                    )
                    still_active.append(m)
                else:
                    if lift <= self.min_delta and others_progress[mid] and epoch > 1:
                        stall[mid] += 1
                    else:
                        stall[mid] = 0
                    if stall[mid] >= self.ef_epochs:
                        failed.add(mid)  # early failure: evict from attempt
                    else:
                        still_active.append(m)
                prev_acc[mid] = accs[mid]
            active = still_active
            if failed:
                break  # report to planner; it decides pruning vs. discard

        accs = validate(store, models)
        last_accs.update(accs)
        ok = meets_targets(
            {m.model_id: accs[m.model_id] for m in models if m.model_id not in failed},
            [m for m in models if m.model_id not in failed],
        ) and not failed
        return MergeResult(ok, last_accs, failed, epoch, self.clock() - t0, frac_log)

"""Layer groups (§5.3): all appearances of one architectural signature across
a workload's models, sorted memory-forward.

    group memory  = leaf_bytes * n_appearances        (what it costs today)
    group savings = leaf_bytes * (n_appearances - 1)  (what merging saves)

GEMEL sorts by group *memory* — "a 100 MB layer that appears in 4 models
would be earlier in the list than a 120 MB layer that appears 3 times".
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from typing import Iterable, Optional

from repro.core.signatures import LayerRecord


def stable_group_id(signature: tuple) -> str:
    """Deterministic shared-buffer id for a group signature.

    ``hash()`` of a tuple varies with PYTHONHASHSEED, so ids built from it
    differ across processes — stores would not be reproducible and two
    builders (ParamStore.merge_group, workload.build_instances) could not
    agree on key names.  blake2b of the signature repr is stable everywhere.
    """
    digest = hashlib.blake2b(repr(signature).encode(), digest_size=8).hexdigest()
    return f"shared:{digest}"


def disambiguate_base(base: str, in_use) -> str:
    """Repeat merges of the same signature (e.g. two disjoint model pairs
    each sharing their own copy of one architecture) must not alias onto one
    buffer: append ``~n`` until no existing key starts with the base.
    ``in_use(prefix)`` reports whether any existing key starts with
    ``prefix``.  Shared by ``ParamStore.merge_group`` and
    ``MergePlan.from_groups`` so live stores and descriptor-scale plans
    agree on key names."""
    if in_use(base + ":"):
        n = 1
        while in_use(f"{base}~{n}:"):
            n += 1
        base = f"{base}~{n}"
    return base


@dataclasses.dataclass
class LayerGroup:
    signature: tuple
    records: list  # list[LayerRecord], >= 2 entries, possibly across models

    @property
    def leaf_bytes(self) -> int:
        return self.records[0].bytes

    @property
    def memory(self) -> int:
        return self.leaf_bytes * len(self.records)

    def columns(self) -> list:
        """Merging is ACROSS models only (paper §4): a model's k-th
        appearance of this signature merges with other models' k-th
        appearances (position-ordered).  Each column becomes one shared
        buffer; a model's internal duplicates stay distinct."""
        from collections import defaultdict

        by_model = defaultdict(list)
        for r in sorted(self.records, key=lambda r: r.position):
            by_model[r.model_id].append(r)
        ncols = max(len(v) for v in by_model.values())
        cols = [[] for _ in range(ncols)]
        for rs in by_model.values():
            for k, r in enumerate(rs):
                cols[k].append(r)
        return cols

    @property
    def savings(self) -> int:
        """bytes saved = leaf_bytes x (appearances - max per-model count):
        the workload still needs one buffer per column."""
        return sum(
            self.leaf_bytes * (len(c) - 1) for c in self.columns()
        )

    @property
    def models(self) -> set:
        return {r.model_id for r in self.records}

    def drop_earliest_half(self) -> "LayerGroup":
        """AIMD multiplicative decrease: drop the half of appearances closest
        to the *start* of their models (they typically hold less memory and
        are harder to share — §5.3)."""
        ordered = sorted(self.records, key=lambda r: r.position)
        keep = ordered[len(ordered) // 2 :]
        return LayerGroup(self.signature, keep)

    def without_models(self, model_ids: set) -> "LayerGroup":
        return LayerGroup(
            self.signature, [r for r in self.records if r.model_id not in model_ids]
        )


def enumerate_groups(
    records: Iterable[LayerRecord], min_appearances: int = 2
) -> list[LayerGroup]:
    """Cluster records by signature; keep groups with >= min_appearances,
    sorted descending by workload memory (memory-forward order)."""
    by_sig: dict[tuple, list] = defaultdict(list)
    for r in records:
        by_sig[r.signature].append(r)
    groups = [
        LayerGroup(sig, recs)
        for sig, recs in by_sig.items()
        if len(recs) >= min_appearances
    ]
    groups.sort(key=lambda g: (-g.memory, g.signature))
    return groups


def potential_savings(records: Iterable[LayerRecord]) -> dict:
    """Fig 5 'Optimal': share every architecturally identical layer,
    disregarding weights/accuracy.  Returns totals in bytes."""
    records = list(records)
    total = sum(r.bytes for r in records)
    groups = enumerate_groups(records)
    saved = sum(g.savings for g in groups)
    return {
        "total_bytes": total,
        "saved_bytes": saved,
        "merged_bytes": total - saved,
        "fraction_saved": saved / total if total else 0.0,
        "n_groups": len(groups),
    }

"""ParamStore — the weight-unification substrate (DESIGN.md A3).

A store holds *physical* buffers keyed by string ids; each model has a
*binding map* ``{leaf_path: store_key}``.  Unmerged models bind every path to
a private key ``"<model>:<path>"``.  Merging a :class:`LayerGroup` rebinds all
member paths to one shared key, initialised from a donor member's weights
(§5.3: "selects initial weights for the newly added group from a random model
that includes that layer").

Because :func:`materialize` is pure index-free dict lookup, ``jax.grad``
through it automatically *sums* gradients from every model into shared
buffers — joint retraining needs no parameter-server machinery.

The store also gives exact memory accounting: resident bytes = unique
buffers, which is precisely what merging saves on the edge box.

**Mesh-sharded serve tier (DESIGN.md S3).**  A store can carry an injected
``placement`` (``distributed.partitioning.MeshPlacement`` — core never
imports ``launch``; the launcher/benchmark builds the logical rules and
hands them in).  With a placement installed the keys become (shard, buffer)
aware: every key has a deterministic *home shard* ``shard_of(key) =
stable_seed(key) % n_shards`` (bookkeeping identity — per-shard epochs and
DMA/residency attribution), mutators ``device_put`` committed buffers under
their binding path's partitioning rules, and :meth:`materialize_bank` places
the stacked suffix bank with its leading bank axis sharded over the mesh's
``model`` axis — a batch-like axis, so the sharded bank GEMM stays bitwise
identical to the unsharded dispatch.  Residency semantics: shared trunk
buffers replicate across shards (every device computes the trunk); private
buffers live on their home shard — :meth:`resident_shards` is the scheduler's
per-device admission view.

Serving additionally relies on **cached materialisation**: bindings change
only at merge/unmerge time (and buffer *values* only at training-commit
time), so the serve loop can reuse one pytree object per model per *binding
epoch* instead of rebuilding the dict/unflatten on every request.  The
``epoch`` counter is bumped by every mutation that could invalidate a
previously returned pytree; :meth:`materialize_cached` is the hot-path
entry point and :attr:`materializations` counts actual rebuilds (one per
model per epoch when the cache works).

**Per-shard epochs**: alongside the global counter, every shard keeps its
own epoch in :attr:`shard_epochs`.  ``bump_epoch(keys=...)`` names the
touched store keys; exactly the home shards of those keys advance once —
the invalidation granularity for per-shard derived state (a shard's bank
slice, its DMA residency).  ``keys=None`` (global invalidation — placement
change, legacy callers) advances every shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

import jax
import numpy as np

from repro.core.groups import LayerGroup, disambiguate_base, stable_group_id
from repro.utils.ids import stable_seed
from repro.utils.tree import flatten_paths, leaf_bytes, unflatten_paths


def _private_key(model_id: str, path: str) -> str:
    return f"{model_id}:{path}"


@dataclasses.dataclass
class ParamStore:
    buffers: dict  # store_key -> array
    bindings: dict  # model_id -> {path: store_key}
    epoch: int = 0  # bumped on every rebinding / buffer-commit
    materializations: dict = dataclasses.field(default_factory=dict)
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)
    # mesh placement (distributed.partitioning.MeshPlacement), injected by
    # the launcher/benchmark — None on a single device (every existing path
    # unchanged).  Duck-typed so core carries no hard jax.sharding surface.
    placement: Optional[Any] = None
    shard_epochs: dict = dataclasses.field(default_factory=dict)

    # -- shard identity -------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self.placement.n_shards if self.placement is not None else 1

    def shard_of(self, key: str) -> int:
        """Deterministic home shard of a store key (bookkeeping identity:
        per-shard epochs, residency/DMA attribution) — stable across
        processes and independent of physical placement."""
        return stable_seed(key) % self.n_shards

    def resident_shards(self, key: str) -> tuple:
        """Shards on which a resident copy of ``key`` lives: shared buffers
        replicate across the mesh (every device runs the trunk), private
        buffers live on their home shard.  The scheduler's per-device
        admission view; recomputed per binding epoch."""
        if self.n_shards == 1:
            return (0,)
        shared = self._cache.get("__shared_keys__")
        if shared is None:
            shared = self._cache["__shared_keys__"] = frozenset(self.shared_keys())
        if key in shared:
            return tuple(range(self.n_shards))
        return (self.shard_of(key),)

    # -- cache bookkeeping ----------------------------------------------------

    def bump_epoch(self, keys: Optional[Iterable] = None) -> int:
        """Invalidate all cached pytrees (bindings or buffer values changed).
        ``keys`` names the store keys the mutation touched: their home shards'
        epochs advance exactly once; ``None`` advances every shard (global
        invalidation)."""
        self.epoch += 1
        shards = (range(self.n_shards) if keys is None
                  else {self.shard_of(k) for k in keys})
        for s in shards:
            self.shard_epochs[s] = self.shard_epochs.get(s, 0) + 1
        self._cache.clear()
        return self.epoch

    def update_buffers(self, new: dict) -> None:
        """Commit new buffer values (e.g. after joint retraining) and
        invalidate cached pytrees that reference the old arrays.  Only the
        touched keys' home shards advance their epoch."""
        if self.placement is not None and new:
            paths = self._paths_for(set(new))
            new = {k: self._place(v, paths.get(k)) for k, v in new.items()}
        self.buffers.update(new)
        self.bump_epoch(keys=new.keys())

    # -- placement ------------------------------------------------------------

    def _place(self, value, path: Optional[str]):
        """``device_put`` a committed buffer under its binding path's
        partitioning rules (no-op without a placement)."""
        if self.placement is None:
            return value
        return self.placement.place(value, path)

    def _paths_for(self, keys: set) -> dict:
        """A representative binding path per key (partitioning rules key on
        the path tail; every binding of a shared key is congruent)."""
        out: dict = {}
        for binding in self.bindings.values():
            for p, k in binding.items():
                if k in keys and k not in out:
                    out[k] = p
        return out

    def set_placement(self, placement: Optional[Any]) -> None:
        """Install (or clear) the mesh placement and re-place every buffer —
        the elastic mesh-change path (``ckpt.reshard.reshard_store``): a plan
        received by a box running a different mesh re-places its buffers
        here.  Global invalidation: every shard's epoch advances once."""
        self.placement = placement
        if placement is not None:
            paths = self._paths_for(set(self.buffers))
            for k in list(self.buffers):
                self.buffers[k] = self._place(self.buffers[k], paths.get(k))
        self.bump_epoch()

    # -- construction ---------------------------------------------------------

    @classmethod
    def from_models(cls, models: dict,
                    placement: Optional[Any] = None) -> "ParamStore":
        """models: {model_id: params_pytree}."""
        buffers: dict = {}
        bindings: dict = {}
        for mid, params in models.items():
            flat = flatten_paths(params)
            bindings[mid] = {}
            for path, leaf in flat.items():
                key = _private_key(mid, path)
                buffers[key] = (placement.place(leaf, path)
                                if placement is not None else leaf)
                bindings[mid][path] = key
        return cls(buffers, bindings, placement=placement)

    # -- merging --------------------------------------------------------------

    def merge_group(self, group: LayerGroup, donor: Optional[tuple] = None,
                    group_id: Optional[str] = None) -> list:
        """Rebind the group's appearances to shared buffers, COLUMN-wise:
        merging is across models only (paper §4) — each model's k-th
        appearance shares with other models' k-th appearances; a model's
        internal duplicates stay distinct.  The first record of each column
        donates the initial weights (§5.3 'from a random model').  Returns
        the shared keys created."""
        base = disambiguate_base(
            group_id or stable_group_id(group.signature),
            lambda p: any(k.startswith(p) for k in self.buffers),
        )
        keys = []
        touched: set = set()
        for ci, col in enumerate(group.columns()):
            if len(col) < 2:
                continue  # single appearance: nothing to share
            gid = f"{base}:c{ci}"
            d = donor if donor and ci == 0 else (col[0].model_id, col[0].path)
            donor_key = self.bindings[d[0]][d[1]]
            self.buffers[gid] = self._place(self.buffers[donor_key],
                                            col[0].path)
            touched.add(gid)
            for r in col:
                old = self.bindings[r.model_id][r.path]
                self.bindings[r.model_id][r.path] = gid
                if old != gid:
                    touched.add(old)
                    self._gc_key(old)
            keys.append(gid)
        if keys:
            self.bump_epoch(keys=touched)
        return keys

    def unmerge(self, group: LayerGroup) -> None:
        """Give every member back a private copy of its current weights
        (used when reverting a failed/drifted configuration)."""
        touched: set = set()
        for r in group.records:
            cur = self.bindings[r.model_id][r.path]
            priv = _private_key(r.model_id, r.path)
            self.buffers[priv] = self._place(self.buffers[cur], r.path)
            self.bindings[r.model_id][r.path] = priv
            touched.update((cur, priv))
        self._gc_unreferenced()  # shared buffers may now be orphaned
        self.bump_epoch(keys=touched)

    def _gc_key(self, key: str) -> None:
        for binding in self.bindings.values():
            if key in binding.values():
                return
        self.buffers.pop(key, None)

    def _gc_unreferenced(self) -> None:
        live = {k for b in self.bindings.values() for k in b.values()}
        for k in list(self.buffers.keys()):
            if k not in live:
                del self.buffers[k]

    # -- plan round-trip (cloud -> edge) ---------------------------------------

    def export_plan(self, groups: list, provenance: Optional[dict] = None,
                    include_weights: bool = False,
                    delta_base: Optional[dict] = None,
                    quantize: bool = False):
        """Build a serializable ``MergePlan`` from committed groups and the
        store's *current* bindings: for each column actually bound to one
        shared (non-private) key, record the key, the donor appearance
        (``merge_group``'s rule: first record of the column) and the member
        records.  Columns that no longer share (e.g. drift-reverted) are
        dropped — the plan reflects store reality, not planner intent.
        ``include_weights`` additionally carries the shared-buffer values so
        a retrained configuration reproduces bitwise on a fresh store.

        ``delta_base`` (key -> previously shipped value) delta-encodes the
        payload against the plan already deployed on the receiving edge box:
        bitwise-unchanged buffers ship as zero-payload ``same`` entries and,
        with ``quantize``, changed buffers as int8 residuals — the
        constrained-link wire format (DESIGN.md S3)."""
        from repro.core.policy import (
            ColumnBinding, MergePlan, PlanGroup, encode_weights,
        )

        pgs = []
        shared: list = []
        for g in groups:
            cols = []
            for col in g.columns():
                if len(col) < 2:
                    continue
                key = self.bindings[col[0].model_id][col[0].path]
                if key == _private_key(col[0].model_id, col[0].path):
                    continue  # not shared
                if any(self.bindings[r.model_id][r.path] != key for r in col):
                    continue  # column split since commit (revert/unmerge)
                cols.append(ColumnBinding(key, (col[0].model_id, col[0].path),
                                          tuple(col)))
                shared.append(key)
            if cols:
                pgs.append(PlanGroup(g.signature, tuple(cols)))
        weights = (encode_weights(self, shared, base=delta_base,
                                  quantize=quantize)
                   if include_weights else None)
        return MergePlan(1, tuple(pgs), provenance or {}, weights)

    def _plan_key_remap(self, plan) -> dict:
        """Guard against the same aliasing ``merge_group`` disambiguates:
        a plan key may already exist in this store bound to a *different*
        group's members (e.g. two disjoint same-architecture pairs merged by
        independent plans).  Remap such a plan group's keys to a fresh
        ``~n`` base; keys whose current owners are all members of the plan's
        own column stay as-is (re-apply / update of the same logical
        buffer)."""
        owners: dict = {}
        for mid, binding in self.bindings.items():
            for path, key in binding.items():
                owners.setdefault(key, set()).add((mid, path))
        taken = set(self.buffers)
        remap: dict = {}
        for pg in plan.groups:
            members_by_key = {
                c.key: {(r.model_id, r.path) for r in c.members}
                for c in pg.columns
            }
            foreign = any(owners.get(k, set()) - members_by_key[k]
                          for k in members_by_key)
            if not foreign:
                taken.update(members_by_key)
                continue
            base = next(iter(members_by_key)).rsplit(":", 1)[0]
            new_base = disambiguate_base(
                base, lambda p: any(k.startswith(p) for k in taken))
            for k in members_by_key:
                remap[k] = new_base + ":" + k.rsplit(":", 1)[1]
                taken.add(remap[k])
        return remap

    def apply_plan(self, plan) -> list:
        """Replay a ``MergePlan`` onto this store: stage every column rebind
        (shared-key value = carried weights if the plan ships them, else the
        recorded donor's current buffer), then commit atomically with ONE
        epoch bump — a live engine re-plans exactly once, and in-flight
        cached pytrees are invalidated in a single step.  Reproduces the
        bindings ``merge_group`` would have built group-by-group; plan keys
        colliding with a foreign group's shared buffers are remapped, never
        silently aliased.

        Delta-encoded weight entries (``same``/``delta_q8`` — export_plan's
        ``delta_base`` path) reconstruct against the buffer this store
        currently holds under the same (post-remap) key: the edge's deployed
        copy of the previously shipped plan."""
        from repro.core.policy import decode_weight

        carried = plan.shared_weights or {}
        remap = self._plan_key_remap(plan)
        staged: list = []  # (key, value, paths, [(model_id, path), ...])
        for pg in plan.groups:
            for col in pg.columns:
                final = remap.get(col.key, col.key)
                if col.key in carried:
                    entry = carried[col.key]
                    base = (self.buffers.get(final)
                            if isinstance(entry, dict)
                            and entry.get("kind", "full") != "full" else None)
                    val = jax.numpy.asarray(decode_weight(entry, base=base))
                else:
                    dm, dp = col.donor
                    val = self.buffers[self.bindings[dm][dp]]
                staged.append(
                    (final, val, col.members[0].path,
                     [(r.model_id, r.path) for r in col.members])
                )
        keys = []
        touched: set = set()
        for key, val, path, members in staged:
            self.buffers[key] = self._place(val, path)
            touched.add(key)
            for mid, mpath in members:
                old = self.bindings[mid][mpath]
                if old != key:
                    touched.add(old)
                self.bindings[mid][mpath] = key
            keys.append(key)
        self._gc_unreferenced()
        if keys:
            self.bump_epoch(keys=touched)
        return keys

    # -- materialisation ------------------------------------------------------

    def materialize(self, model_id: str, buffers: Optional[dict] = None) -> dict:
        """Nested params for one model.  Pass ``buffers`` explicitly inside a
        jitted/grad'd function so tracing sees them as inputs."""
        buffers = self.buffers if buffers is None else buffers
        binding = self.bindings[model_id]
        return unflatten_paths({p: buffers[k] for p, k in binding.items()})

    def materialize_cached(self, model_id: str) -> dict:
        """Serve-path materialisation: returns the *same* pytree object for a
        model until the next binding epoch (merge/unmerge/buffer commit), so
        per-request cost is one dict lookup instead of a full unflatten.
        Callers must treat the result as read-only."""
        hit = self._cache.get(model_id)
        if hit is not None:
            return hit
        tree = self.materialize(model_id)
        self._cache[model_id] = tree
        self.materializations[model_id] = self.materializations.get(model_id, 0) + 1
        return tree

    @staticmethod
    def bank_id(model_ids: tuple) -> str:
        """Materialisation-counter key for a suffix bank over ``model_ids``."""
        return "bank:" + "+".join(model_ids)

    def materialize_bank(self, model_ids: tuple, paths=None) -> dict:
        """Suffix-bank materialisation (DESIGN.md S2): one pytree whose every
        leaf is the members' buffers stacked on a leading bank axis —
        ``leaf[path][n] == buffers[bindings[model_ids[n]][path]]`` — restricted
        to ``paths`` (typically the private-suffix paths).  Members must bind
        congruent shapes at every stacked path; the serving engine checks the
        adapters' suffix signatures before asking for a bank.

        Cached per binding epoch exactly like :meth:`materialize_cached`
        (``bump_epoch`` clears the shared cache), so merge/unmerge/
        ``update_buffers``/``apply_plan`` all invalidate the bank; rebuild
        counts land in :attr:`materializations` under :meth:`bank_id`."""
        model_ids = tuple(model_ids)
        pkey = None if paths is None else frozenset(paths)
        ckey = ("__bank__", model_ids, pkey)
        hit = self._cache.get(ckey)
        if hit is not None:
            return hit
        use = sorted(self.bindings[model_ids[0]]) if paths is None else sorted(pkey)
        flat = {
            p: jax.numpy.stack(
                [self.buffers[self.bindings[m][p]] for m in model_ids])
            for p in use
        }
        if self.placement is not None:
            # Bank axis (leading, batch-like) sharded over the mesh's model
            # axis — the sharded bank GEMM's input placement (DESIGN.md S3).
            flat = {p: self.placement.place_bank(a) for p, a in flat.items()}
        tree = unflatten_paths(flat)
        self._cache[ckey] = tree
        bid = self.bank_id(model_ids)
        self.materializations[bid] = self.materializations.get(bid, 0) + 1
        return tree

    # -- accounting -----------------------------------------------------------

    def resident_bytes(self, model_ids: Optional[list] = None) -> int:
        """Unique buffer bytes for a set of models (the edge-box footprint)."""
        ids = model_ids if model_ids is not None else list(self.bindings.keys())
        keys = {self.bindings[m][p] for m in ids for p in self.bindings[m]}
        return sum(leaf_bytes(self.buffers[k]) for k in keys)

    def resident_bytes_by_shard(self, model_ids: Optional[list] = None) -> dict:
        """Per-shard resident bytes for a set of models: shared buffers count
        on every shard (replicated trunk), private buffers on their home
        shard — the per-device admission view the sharded scheduler budgets
        against."""
        ids = model_ids if model_ids is not None else list(self.bindings.keys())
        keys = {self.bindings[m][p] for m in ids for p in self.bindings[m]}
        out = {s: 0 for s in range(self.n_shards)}
        for k in keys:
            nbytes = leaf_bytes(self.buffers[k])
            for s in self.resident_shards(k):
                out[s] += nbytes
        return out

    def model_bytes(self, model_id: str) -> int:
        return sum(
            leaf_bytes(self.buffers[k]) for k in set(self.bindings[model_id].values())
        )

    def shared_keys(self) -> set:
        counts: dict[str, int] = {}
        for b in self.bindings.values():
            for k in set(b.values()):
                counts[k] = counts.get(k, 0) + 1
        return {k for k, c in counts.items() if c > 1}

    def incremental_load_bytes(self, next_model: str, resident: set) -> int:
        """Bytes that must be DMA'd to run ``next_model`` given the set of
        store keys already resident — the merging-aware swap cost (§5.4)."""
        needed = set(self.bindings[next_model].values())
        return sum(leaf_bytes(self.buffers[k]) for k in needed - resident)

    def keys_for(self, model_id: str) -> set:
        return set(self.bindings[model_id].values())

    def binding_signature(self, model_id: str, paths: Optional[set] = None) -> tuple:
        """Hashable fingerprint of (path -> store key) for a subset of paths.
        Two models whose fingerprints over a prefix's paths are equal execute
        that prefix on *identical* weights — the shared-stem detection used by
        the serving engine's batched prefix execution."""
        b = self.bindings[model_id]
        use = sorted(paths) if paths is not None else sorted(b.keys())
        return tuple((p, b[p]) for p in use)

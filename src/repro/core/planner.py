"""Incremental AIMD merging planner (§5.3) — compatibility surface.

The planning stack now lives in :mod:`repro.core.policy` as a staged,
pluggable subsystem (enumerate -> score/prefilter -> attempt ->
commit/rollback) with a ``CandidateScorer`` interface, an optional
simulator-in-the-loop objective, injectable timing, and a serializable
:class:`~repro.core.policy.MergePlan` output.

:class:`IncrementalMerger` is the historical entry point: a
:class:`~repro.core.policy.StagedPlanner` with the paper's memory-forward
scorer by default.  Existing callers (tests, examples, benchmarks) keep
working unchanged; new callers should parameterise ``scorer=`` /
``objective=`` directly.

The planner never touches accuracy guarantees itself — the trainer's
validation is the gate (observation "violations only delay, never breach").
"""
from __future__ import annotations

from repro.core.policy import (  # noqa: F401  (re-exported compat names)
    MemoryForwardScorer,
    MergeEvent,
    MergePlan,
    PlanResult,
    RepresentationSimilarityScorer,
    StagedPlanner,
)


class IncrementalMerger(StagedPlanner):
    """Drop-in name for the seed planner: memory-forward order, full AIMD
    retry loop, now returning a :class:`PlanResult` whose ``plan`` field is
    the serializable MergePlan artifact."""

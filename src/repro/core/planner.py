"""Incremental AIMD merging planner (§5.3).

Process:
  1. enumerate layer groups across the workload, sort memory-forward;
  2. take the next group, attempt to share it across *all* appearances;
  3. retrain jointly (merging.MergeTrainer or injected surrogate);
  4. on success: commit (weights stay in the store), log savings, ship to
     edge (event log records bandwidth), move to next group;
  5. on failure: prune early-failed models if reported; otherwise halve the
     group dropping the earliest-position appearances.  Retry while the
     remainder's memory exceeds the next group's, else discard.  Retraining
     always resumes from the last *successful* iteration's weights.

The planner never touches accuracy guarantees itself — the trainer's
validation is the gate (observation "violations only delay, never breach").
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Callable, Optional

from repro.core.groups import LayerGroup, enumerate_groups
from repro.core.store import ParamStore
from repro.core.validation import RegisteredModel
from repro.utils.tree import leaf_bytes


@dataclasses.dataclass
class MergeEvent:
    """One committed merging iteration — drives Figs 13 (savings over time)
    and 14 (cloud→edge bandwidth: weights for all involved models ship)."""

    time: float  # seconds since merging started
    group_signature: tuple
    n_appearances: int
    saved_bytes: int  # incremental savings from this group
    cumulative_saved: int
    shipped_bytes: int  # weights shipped to the edge for this update
    accuracies: dict


@dataclasses.dataclass
class PlanResult:
    store: ParamStore
    events: list
    attempted: int
    committed: int
    discarded: int
    baseline_bytes: int
    final_bytes: int

    @property
    def saved_bytes(self) -> int:
        return self.baseline_bytes - self.final_bytes

    @property
    def fraction_saved(self) -> float:
        return self.saved_bytes / max(self.baseline_bytes, 1)


class IncrementalMerger:
    def __init__(
        self,
        store: ParamStore,
        models: list,  # list[RegisteredModel]
        records: list,  # list[LayerRecord] for the workload
        trainer=None,  # object with .train(store, models) -> MergeResult
        time_budget_s: Optional[float] = None,
        min_group_bytes: int = 1,
        on_commit: Optional[Callable] = None,
    ):
        self.store = store
        self.models = {m.model_id: m for m in models}
        self.groups = enumerate_groups(records)
        self.trainer = trainer
        self.time_budget_s = time_budget_s
        self.min_group_bytes = min_group_bytes
        self.on_commit = on_commit

    def _snapshot(self):
        return dict(self.store.buffers), {
            m: dict(b) for m, b in self.store.bindings.items()
        }

    def _restore(self, snap):
        self.store.buffers, self.store.bindings = snap[0], snap[1]
        self.store.bump_epoch()  # rollback rebinds: invalidate cached pytrees

    def _involved(self, group: LayerGroup) -> list:
        return [self.models[mid] for mid in sorted(group.models) if mid in self.models]

    def run(self) -> PlanResult:
        t0 = time.monotonic()
        baseline = self.store.resident_bytes()
        events: list = []
        attempted = committed = discarded = 0
        cumulative_saved = 0

        queue = list(self.groups)
        qi = 0
        while qi < len(queue):
            if self.time_budget_s is not None and time.monotonic() - t0 > self.time_budget_s:
                break
            group = queue[qi]
            next_mem = queue[qi + 1].memory if qi + 1 < len(queue) else 0

            while True:  # AIMD retry loop on this group
                if len(group.records) < 2 or group.savings < self.min_group_bytes:
                    discarded += 1
                    break
                attempted += 1
                snap = self._snapshot()
                before = self.store.resident_bytes()
                self.store.merge_group(group)
                result = self.trainer.train(self.store, self._involved(group))

                if result.success:
                    committed += 1
                    after = self.store.resident_bytes()
                    saved = before - after
                    cumulative_saved += saved
                    shipped = sum(
                        self.store.model_bytes(mid) for mid in sorted(group.models)
                    )
                    ev = MergeEvent(
                        time.monotonic() - t0, group.signature, len(group.records),
                        saved, cumulative_saved, shipped, result.accuracies,
                    )
                    events.append(ev)
                    if self.on_commit:
                        self.on_commit(ev, self.store)
                    break

                # failure: roll back weights/bindings to last successful state
                self._restore(snap)
                if result.failed_models:
                    group = group.without_models(result.failed_models)
                else:
                    group = group.drop_earliest_half()
                # keep retrying only while the shrunken group still out-ranks
                # the next group in the sorted list (§5.3)
                if group.memory <= next_mem or len(group.records) < 2:
                    discarded += 1
                    break
            qi += 1

        return PlanResult(
            self.store, events, attempted, committed, discarded,
            baseline, self.store.resident_bytes(),
        )

"""Pluggable merge-policy subsystem (DESIGN.md P1).

The §5.3 search is decomposed into explicit stages driven by a
:class:`StagedPlanner`:

    enumerate -> score/prefilter -> attempt -> commit/rollback

with two pluggable axes:

* **CandidateScorer** — owns the ordering of candidate groups and an optional
  training-free *prefilter* that refines or discards candidates before any
  retraining is spent.  :class:`MemoryForwardScorer` reproduces the paper's
  memory-forward order exactly; :class:`RepresentationSimilarityScorer`
  additionally runs calibration activations through each model (arXiv
  2410.11233: activation similarity ranks shareable layers *without*
  training) and drops group members whose representations diverge — the
  expensive retraining attempt then starts from a configuration that is
  likely to survive validation.

* **Objective** — an optional callable ``objective(store, committed_groups)
  -> float`` scoring the *deployed* quality of the plan-so-far (e.g. the
  simulator's effective accuracy, the Fig 6/10 quantity, via
  ``serving.simulator.effective_accuracy_objective``).  When set, a commit
  that regresses the objective beyond ``objective_tolerance`` is rolled
  back even though retraining succeeded: the planner optimises what the
  edge box actually serves, not raw bytes.

The planner's output is a first-class :class:`MergePlan` — ordered committed
groups, per-column binding deltas (shared key + donor + members) and
provenance — that is JSON-serializable and round-trips cloud→edge:
``ParamStore.export_plan`` builds one from a live store,
``ParamStore.apply_plan`` replays it onto a fresh store with a *single*
epoch bump, and ``MergeAwareEngine.apply_plan`` hot-swaps it under a live
serve loop without dropping in-flight requests.
"""
from __future__ import annotations

import base64
import dataclasses
import inspect
import json
import time
from typing import Callable, Optional

import numpy as np

from repro.core.groups import (
    LayerGroup, disambiguate_base, enumerate_groups, stable_group_id,
)
from repro.core.signatures import (
    LayerRecord, record_from_json, record_to_json, signature_from_json,
    signature_to_json,
)
from repro.core.store import ParamStore


# ---------------------------------------------------------------------------
# MergePlan — the serializable planning artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColumnBinding:
    """One shared buffer: its store key, the member appearances rebound to
    it, and the donor appearance whose weights initialise it (§5.3 'from a
    random model') when the plan does not carry trained weights."""

    key: str
    donor: tuple  # (model_id, path)
    members: tuple  # tuple[LayerRecord, ...] in merge (position) order


@dataclasses.dataclass(frozen=True)
class PlanGroup:
    signature: tuple
    columns: tuple  # tuple[ColumnBinding, ...]


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """Ordered committed groups + binding deltas + provenance.

    ``shared_weights`` optionally carries the trained shared-buffer values
    (base64 of the raw array bytes) so a plan exported after joint
    retraining reproduces serving outputs bitwise on a fresh store; without
    it, ``apply_plan`` initialises each shared key from the recorded donor —
    exactly what ``merge_group`` does.
    """

    version: int
    groups: tuple  # tuple[PlanGroup, ...] in commit order
    provenance: dict
    shared_weights: Optional[dict] = None  # key -> {dtype, shape, data(b64)}

    # -- derived views --------------------------------------------------------

    def binding_deltas(self) -> dict:
        """{(model_id, path): shared_key} for every rebound appearance —
        what scheduler/workload instance building consumes."""
        out = {}
        for pg in self.groups:
            for col in pg.columns:
                for r in col.members:
                    out[(r.model_id, r.path)] = col.key
        return out

    def layer_groups(self) -> list:
        """Committed groups as :class:`LayerGroup`s (e.g. for the simulator
        or ``build_instances(merged="groups")`` compatibility paths)."""
        return [
            LayerGroup(pg.signature, [r for col in pg.columns for r in col.members])
            for pg in self.groups
        ]

    def models(self) -> set:
        return {r.model_id for pg in self.groups for c in pg.columns
                for r in c.members}

    # -- serialization --------------------------------------------------------

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({
            "version": self.version,
            "groups": [
                {
                    "signature": signature_to_json(pg.signature),
                    "columns": [
                        {
                            "key": c.key,
                            "donor": list(c.donor),
                            "members": [record_to_json(r) for r in c.members],
                        }
                        for c in pg.columns
                    ],
                }
                for pg in self.groups
            ],
            "provenance": self.provenance,
            "shared_weights": self.shared_weights,
        }, indent=indent)

    @classmethod
    def from_json(cls, payload: str) -> "MergePlan":
        obj = json.loads(payload)
        groups = []
        for pg in obj["groups"]:
            sig = signature_from_json(pg["signature"])
            cols = tuple(
                ColumnBinding(
                    c["key"], tuple(c["donor"]),
                    tuple(record_from_json(m, sig) for m in c["members"]),
                )
                for c in pg["columns"]
            )
            groups.append(PlanGroup(sig, cols))
        return cls(obj["version"], tuple(groups), obj["provenance"],
                   obj.get("shared_weights"))

    # -- construction without a live store ------------------------------------

    @classmethod
    def from_groups(cls, groups: list, provenance: Optional[dict] = None) -> "MergePlan":
        """Build a plan straight from committed :class:`LayerGroup`s using
        the same deterministic key naming as ``ParamStore.merge_group``
        (blake2 base + ``~n`` repeat-signature disambiguation + ``:cN``
        columns) — descriptor-scale planners (no weights allocated) ship
        plans through the identical schema."""
        used: set = set()
        pgs = []
        for g in groups:
            base = disambiguate_base(
                stable_group_id(g.signature),
                lambda p: any(k.startswith(p) for k in used),
            )
            cols = []
            for ci, col in enumerate(g.columns()):
                if len(col) < 2:
                    continue
                key = f"{base}:c{ci}"
                used.add(key)
                cols.append(ColumnBinding(key, (col[0].model_id, col[0].path),
                                          tuple(col)))
            if cols:
                pgs.append(PlanGroup(g.signature, tuple(cols)))
        return cls(1, tuple(pgs), provenance or {})


def encode_weights(store: ParamStore, keys: list,
                   base: Optional[dict] = None,
                   quantize: bool = False) -> dict:
    """Serialize shared-buffer values for a plan payload.  ``base`` maps a
    key to the value the receiving edge box currently holds under it (the
    previously deployed plan): unchanged buffers ship as zero-payload
    ``same`` entries and, with ``quantize``, changed float buffers ship as
    int8 residuals — the delta-compressed wire format (DESIGN.md S3).
    Without ``base`` every entry is a ``full`` bitwise payload."""
    from repro.core.signatures import encode_weight_entry

    out = {}
    for k in keys:
        arr = np.asarray(store.buffers[k])
        out[k] = encode_weight_entry(
            arr, base=None if base is None else base.get(k),
            quantize=quantize)
    return out


def decode_weight(entry: dict, base=None):
    from repro.core.signatures import decode_weight_entry

    return decode_weight_entry(entry, base=base)


# ---------------------------------------------------------------------------
# CandidateScorer interface
# ---------------------------------------------------------------------------


class CandidateScorer:
    """Orders candidate groups (higher score attempted first) and optionally
    refines/prunes them before retraining is spent."""

    name = "scorer"

    def score(self, group: LayerGroup) -> float:
        raise NotImplementedError

    def prefilter(self, groups: list) -> tuple:
        """Returns (kept, pruned).  ``kept`` entries may be *refined* groups
        (members dropped); ``pruned`` lists candidates rejected outright."""
        return list(groups), []

    def order(self, groups: list) -> list:
        return sorted(groups, key=lambda g: (-self.score(g), g.signature))


class MemoryForwardScorer(CandidateScorer):
    """The paper's §5.3 order: group memory descending ("a 100 MB layer that
    appears in 4 models comes before a 120 MB layer appearing 3 times")."""

    name = "memory-forward"

    def score(self, group: LayerGroup) -> float:
        return float(group.memory)


def activation_gram(x) -> np.ndarray:
    """Centered sample-space Gram K = X Xᵀ of an (N, ...) activation batch —
    the O(N²·D) building block of linear CKA (features can be wide; batches
    are small, so never form the D×D feature Gram)."""
    x = np.asarray(x, dtype=np.float64).reshape(x.shape[0], -1)
    x = x - x.mean(axis=0, keepdims=True)
    return x @ x.T


def cka_from_grams(kx: np.ndarray, ky: np.ndarray) -> float:
    """CKA(X, Y) = tr(KxKy) / (||Kx||_F ||Ky||_F) for centered Grams —
    identical to ||XᵀY||²_F / (||XᵀX||_F ||YᵀY||_F)."""
    hsic = float(np.sum(kx * ky))
    denom = float(np.linalg.norm(kx) * np.linalg.norm(ky))
    if denom < 1e-12:
        return 0.0
    return hsic / denom


def linear_cka(x, y) -> float:
    """Linear CKA between two (N, ...) activation batches (arXiv 2410.11233
    uses representation similarity as the sharing guide; linear CKA is its
    training-free workhorse).  Flattens non-batch dims, centers features."""
    return cka_from_grams(activation_gram(x), activation_gram(y))


def default_layer_key(path: str) -> str:
    """Map a param path to the layer whose activation probes it: drop the
    final leaf segment ("stage0/0/conv1/w" -> "stage0/0/conv1")."""
    return path.rsplit("/", 1)[0] if "/" in path else path


def calibration_activations(members: dict, batch: dict) -> dict:
    """Activation payload for the scorer/surrogate, computed through each
    family's ``MergeableAdapter`` — the policy layer never calls a family's
    private tap helpers (DESIGN.md P3 boundary).

    ``members``: {model_id: (adapter, cfg, params)}.  The same ``batch``
    runs through every model so similarities compare responses to identical
    inputs.  Returns {model_id: {layer_key: (N, ...) array}}."""
    return {
        mid: adapter.layer_activations(cfg, params, batch)
        for mid, (adapter, cfg, params) in members.items()
    }


# Mixed-zoo trunk eligibility (ISSUE 10): families whose trunk-internal
# layers are op-congruent and may share a buffer when signatures match.
# dense and moe blocks run the identical attention op sequence, so their
# attn/norm leaves are mutually mergeable; the ssm mixer and the griffin
# recurrence are different computations even where a shape coincides, so a
# trunk column never mixes them with transformer trunks.  Families absent
# from every class only trunk-merge with themselves.
TRUNK_COMPATIBLE: tuple = (frozenset({"dense", "moe"}),)

# Interface layers — token embedding and the final-norm/unembed suffix —
# compute the same op in every LM family, so cross-family sharing is decided
# purely by signature + CKA there (the "embeddings/norms may merge" half of
# the mixed-zoo eligibility matrix, DESIGN.md).
INTERFACE_PREFIXES: tuple = ("embed", "final_norm", "lm_head")


def trunk_mergeable(fam_a: Optional[str], fam_b: Optional[str]) -> bool:
    """May trunk-internal layers of these two families share a buffer?
    Unknown families are conservatively treated as self-only."""
    if fam_a == fam_b:
        return True
    if fam_a is None or fam_b is None:
        return False
    return any(fam_a in c and fam_b in c for c in TRUNK_COMPATIBLE)


def is_interface_path(path: str) -> bool:
    return path.split("/", 1)[0] in INTERFACE_PREFIXES


class RepresentationSimilarityScorer(MemoryForwardScorer):
    """Training-free prefilter: prune group members whose calibration-batch
    activations diverge from the rest of their column, *before* any retrain
    is spent.  Ordering among survivors stays memory-forward (§5.3), so the
    scorer only removes work, never reorders it.

    ``activations``: {model_id: {layer_key: (N, ...) array}} — each model's
    responses to a common calibration batch, keyed by the layer the param
    path belongs to (see :func:`default_layer_key`).  Records with no probe
    are conservatively kept (unknown ≠ dissimilar).

    ``families``: optional {model_id: family_name} enabling the mixed-zoo
    eligibility matrix — trunk-internal columns are first reduced to their
    largest :func:`trunk_mergeable` class (shape coincidence across e.g. an
    ssm mixer and a transformer projection is not op-congruence), while
    interface layers (:data:`INTERFACE_PREFIXES`) stay cross-family and CKA
    arbitrates as usual.
    """

    name = "representation-similarity"

    def __init__(self, activations: dict, min_similarity: float = 0.5,
                 layer_key: Optional[Callable] = None,
                 families: Optional[dict] = None):
        self.activations = activations
        self.min_similarity = min_similarity
        self._layer_key = layer_key or default_layer_key
        self.families = families
        self.pruned_members = 0
        self.pruned_groups = 0
        self.pruned_cross_family = 0
        self._sim_cache: dict = {}
        self._gram_cache: dict = {}

    @classmethod
    def from_adapters(cls, members: dict, batch: dict,
                      min_similarity: float = 0.5,
                      layer_key: Optional[Callable] = None):
        """Build the scorer through the adapter contract:
        ``members = {model_id: (adapter, cfg, params)}`` plus one shared
        calibration batch — any registered family calibrates.  Family
        eligibility (mixed zoo) comes from each adapter's ``family`` tag."""
        return cls(calibration_activations(members, batch), min_similarity,
                   layer_key=layer_key,
                   families={mid: adapter.family
                             for mid, (adapter, _, __) in members.items()})

    def _family_filter(self, col: list) -> list:
        """Mixed-zoo eligibility: keep the largest trunk-compatible class of
        a trunk-internal column (deterministic tie-break: the class whose
        sorted member keys come first).  Interface layers pass through."""
        if not self.families or all(
                is_interface_path(r.path) for r in col):
            return col
        classes: list = []
        for r in col:
            fam = self.families.get(r.model_id)
            for cl in classes:
                if trunk_mergeable(fam, self.families.get(cl[0].model_id)):
                    cl.append(r)
                    break
            else:
                classes.append([r])
        best = min(classes, key=lambda cl: (-len(cl),
                                            sorted(r.key for r in cl)[0]))
        self.pruned_cross_family += len(col) - len(best)
        return best

    def _gram(self, record: LayerRecord):
        lk = self._layer_key(record.path)
        ck = (record.model_id, lk)
        if ck not in self._gram_cache:
            act = self.activations.get(record.model_id, {}).get(lk)
            self._gram_cache[ck] = (None if act is None
                                    else activation_gram(act))
        return self._gram_cache[ck]

    def _pair(self, a: LayerRecord, b: LayerRecord) -> Optional[float]:
        ka, kb = self._gram(a), self._gram(b)
        if ka is None or kb is None:
            return None
        ck = (a.model_id, self._layer_key(a.path),
              b.model_id, self._layer_key(b.path))
        if ck not in self._sim_cache:
            self._sim_cache[ck] = cka_from_grams(ka, kb)
        return self._sim_cache[ck]

    def column_similarities(self, col: list) -> dict:
        """record.key -> mean pairwise CKA with the other probed members
        (None when the record has no probe)."""
        out = {}
        for r in col:
            sims = [s for o in col if o is not r
                    for s in [self._pair(r, o)] if s is not None]
            out[r.key] = float(np.mean(sims)) if sims else None
        return out

    def column_cluster(self, col: list) -> tuple:
        """Largest mutually-coherent subset of a column's members (sharing a
        buffer requires MUTUAL similarity, not similarity on average): seed
        with the most similar probed pair, greedily grow by the member whose
        *minimum* similarity to the cluster stays >= ``min_similarity``.
        Unprobed members are conservatively kept.  Returns (kept_records,
        observed_similarities)."""
        probed = [r for r in col if self._gram(r) is not None]
        unprobed = [r for r in col if self._gram(r) is None]
        sims: dict = {}
        best_pair, best = None, -1.0
        for i in range(len(probed)):
            for j in range(i + 1, len(probed)):
                s = self._pair(probed[i], probed[j])
                sims[(i, j)] = sims[(j, i)] = s
                if s > best:
                    best, best_pair = s, (i, j)
        observed = [sims[(i, j)] for i in range(len(probed))
                    for j in range(i + 1, len(probed))]
        if best_pair is None:
            return list(col), observed  # nothing probed: keep everything
        if best < self.min_similarity:
            # no coherent pair at all — only unprobed members could share
            return (unprobed if len(unprobed) >= 2 else []), observed
        cluster = set(best_pair)
        candidates = set(range(len(probed))) - cluster
        while candidates:
            gains = {c: min(sims[(c, m)] for m in cluster) for c in candidates}
            c = max(sorted(gains), key=lambda k: gains[k])
            if gains[c] < self.min_similarity:
                break
            cluster.add(c)
            candidates.remove(c)
        keep = [r for i, r in enumerate(probed) if i in cluster] + unprobed
        return keep, observed

    def refine(self, group: LayerGroup) -> tuple:
        """Shrink each column to its coherent cluster; returns
        (refined_group | None, similarities observed).  A column with no
        coherent pair dies entirely — nothing in it is worth a retrain.

        Column alignment is preserved: ``LayerGroup.columns()`` ranks a
        model's appearances positionally, so once a model loses an
        appearance in column *k*, its later appearances would shift into
        earlier columns and pair with members whose mutual coherence was
        never scored.  Such models are therefore dropped from all later
        columns too (kept appearances stay a positional prefix) —
        conservative, but every surviving pairing was actually scored.
        Pure query: prune accounting happens in :meth:`prefilter`."""
        kept, sims = [], []
        broken: set = set()  # models whose appearance chain broke earlier
        for col in group.columns():
            col = [r for r in col if r.model_id not in broken]
            if len(col) >= 2:
                fcol = self._family_filter(col)
                broken |= ({r.model_id for r in col}
                           - {r.model_id for r in fcol})
                col = fcol
            if len(col) < 2:
                kept.extend(col)  # unshared appearance: keeps ranks aligned
                continue
            kcol, observed = self.column_cluster(col)
            sims.extend(observed)
            if len(kcol) >= 2:
                broken |= ({r.model_id for r in col}
                           - {r.model_id for r in kcol})
                kept.extend(kcol)
            else:
                broken |= {r.model_id for r in col}
        refined = LayerGroup(group.signature, kept) if len(kept) >= 2 else None
        if refined is not None and not any(
                len(c) >= 2 for c in refined.columns()):
            refined = None
        return refined, sims

    def similarity(self, group: LayerGroup) -> float:
        _, sims = self.refine(group)
        return float(np.mean(sims)) if sims else 1.0

    def prefilter(self, groups: list) -> tuple:
        kept, pruned = [], []
        for g in groups:
            refined, _ = self.refine(g)
            if refined is None:
                self.pruned_groups += 1
                self.pruned_members += len(g.records)
                pruned.append(g)
            else:
                self.pruned_members += len(g.records) - len(refined.records)
                kept.append(refined)
        return kept, pruned


class CoherenceSurrogateTrainer:
    """Training-free stand-in for ``MergeTrainer`` used by fast tests,
    benchmarks and examples: a configuration survives "retraining" iff every
    shared column is a mutually coherent cluster on the calibration batch
    (same :meth:`RepresentationSimilarityScorer.column_cluster` ground truth
    the prefilter predicts); members outside the largest coherent cluster
    are reported as early failures (§5.3 eviction).  Each ``train`` call
    counts as one retraining attempt."""

    def __init__(self, activations: dict, min_similarity: float = 0.5,
                 layer_key: Optional[Callable] = None):
        self.probe = RepresentationSimilarityScorer(
            activations, min_similarity, layer_key=layer_key)
        self.calls = 0

    @classmethod
    def from_adapters(cls, members: dict, batch: dict,
                      min_similarity: float = 0.5,
                      layer_key: Optional[Callable] = None):
        """Adapter-contract constructor, mirroring
        ``RepresentationSimilarityScorer.from_adapters``."""
        return cls(calibration_activations(members, batch), min_similarity,
                   layer_key=layer_key)

    def train(self, store, models, group=None):
        from repro.core.merging import MergeResult

        self.calls += 1
        failed: set = set()
        for col in group.columns():
            if len(col) < 2:
                continue
            keep, _ = self.probe.column_cluster(col)
            failed |= {r.model_id for r in col} - {r.model_id for r in keep}
        accs = {m.model_id: (0.0 if m.model_id in failed else 1.0)
                for m in models}
        return MergeResult(not failed, accs, failed, 1, 0.0, [])


# ---------------------------------------------------------------------------
# Staged planner — enumerate -> score -> attempt -> commit/rollback
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MergeEvent:
    """One committed merging iteration — drives Figs 13 (savings over time)
    and 14 (cloud→edge bandwidth: weights for all involved models ship)."""

    time: float  # seconds since merging started (planner clock)
    group_signature: tuple
    n_appearances: int
    saved_bytes: int  # incremental savings from this group
    cumulative_saved: int
    shipped_bytes: int  # weights shipped to the edge for this update
    accuracies: dict
    objective: Optional[float] = None  # simulator-in-the-loop score, if set


@dataclasses.dataclass(frozen=True)
class CascadeProfile:
    """Observed cascade behavior of the serving front-end, as planner input
    (DESIGN.md F1): per-instance heavy-path hit-rates (the fraction of
    offered frames the gate sends to the heavy merged group) and the
    accuracy credit a gate-only completion earns.  Produced by
    ``serving.ingestion.IngestionFrontEnd.cascade_profile`` and consumed by
    ``serving.simulator.effective_accuracy_objective(cascade=...)`` — when
    only 40% of a camera's frames reach the heavy model, that model's
    residency is worth proportionally less swap pressure, and the planner
    should score candidate merges against THAT arrival process, not the
    raw one."""

    rates: dict  # instance_id -> hit rate in [0, 1]
    gate_accuracy: dict  # instance_id -> gate-only accuracy credit in [0, 1]

    def simulator_arg(self) -> dict:
        """The ``cascade=`` mapping ``simulator.simulate`` consumes:
        {instance_id: (hit_rate, gate_accuracy)}."""
        return {iid: (float(self.rates[iid]),
                      float(self.gate_accuracy.get(iid, 0.0)))
                for iid in self.rates}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({
            "rates": {k: float(v) for k, v in sorted(self.rates.items())},
            "gate_accuracy": {k: float(v) for k, v in
                              sorted(self.gate_accuracy.items())},
        }, indent=indent)

    @classmethod
    def from_json(cls, payload: str) -> "CascadeProfile":
        obj = json.loads(payload)
        return cls(dict(obj["rates"]), dict(obj["gate_accuracy"]))


@dataclasses.dataclass
class PlanResult:
    store: ParamStore
    events: list
    attempted: int
    committed: int
    discarded: int
    baseline_bytes: int
    final_bytes: int
    pruned: int = 0  # candidates removed by the scorer prefilter
    plan: Optional[MergePlan] = None
    timed_out: bool = False  # an attempt blew attempt_budget_s; plan truncated

    @property
    def saved_bytes(self) -> int:
        return self.baseline_bytes - self.final_bytes

    @property
    def fraction_saved(self) -> float:
        return self.saved_bytes / max(self.baseline_bytes, 1)


class StagedPlanner:
    """Incremental AIMD merging planner (§5.3), staged and pluggable.

    Stages:
      1. **enumerate** — layer groups across the workload;
      2. **score** — ``scorer.prefilter`` refines/prunes candidates without
         training, ``scorer.order`` ranks the survivors (memory-forward by
         default);
      3. **attempt** — take the next group, rebind it shared, retrain
         jointly (``core.merging.MergeTrainer`` or injected surrogate);
      4. **commit/rollback** — on trainer success (and, when an
         ``objective`` is set, no objective regression) the weights stay;
         otherwise roll back and AIMD-shrink: prune early-failed models if
         reported, else halve dropping earliest-position appearances, and
         retry while the remainder still out-ranks the next candidate.

    Timing is injectable (``clock=``, default ``time.monotonic``) so event
    traces and budget handling are deterministic under test.  The result
    carries a serializable :class:`MergePlan` built from the committed
    groups (``ParamStore.export_plan``).
    """

    def __init__(
        self,
        store: ParamStore,
        models: list,  # list[RegisteredModel]
        records: list,  # list[LayerRecord] for the workload
        trainer=None,  # object with .train(store, models) -> MergeResult
        time_budget_s: Optional[float] = None,
        attempt_budget_s: Optional[float] = None,
        min_group_bytes: int = 1,
        on_commit: Optional[Callable] = None,
        scorer: Optional[CandidateScorer] = None,
        objective: Optional[Callable] = None,  # (store, groups) -> float
        objective_tolerance: float = 0.0,
        clock: Callable[[], float] = time.monotonic,
        plan_weights: bool = True,
        exclude_models: Optional[set] = None,
        seed_plan: Optional["MergePlan"] = None,
    ):
        self.store = store
        self.models = {m.model_id: m for m in models}
        self.records = list(records)
        self.trainer = trainer
        self.time_budget_s = time_budget_s
        # per-ATTEMPT ceiling (injected clock): one pathological retrain in a
        # warm-started re-plan must not stall the lifecycle's breached→swapped
        # transition indefinitely.  When an attempt exceeds it, the planner
        # stops and ships whatever committed — flagged via
        # ``PlanResult.timed_out`` / provenance["replan_timed_out"], which
        # LifecycleController surfaces in ResumeState.
        self.attempt_budget_s = attempt_budget_s
        self.timed_out = False
        self.min_group_bytes = min_group_bytes
        self.on_commit = on_commit
        self.scorer = scorer or MemoryForwardScorer()
        self.objective = objective
        self.objective_tolerance = objective_tolerance
        self.clock = clock
        # ship the trained shared-buffer values in the plan (paper: merged
        # weights DO go cloud->edge).  Retraining commits new values, so a
        # weightless plan would rebuild the pre-retraining configuration on
        # the edge — never-validated weights.  Disable only for
        # descriptor-scale planning or when the trainer provably does not
        # mutate buffers.
        self.plan_weights = plan_weights
        # drift-adapt warm start (DESIGN.md L1): models to leave out of the
        # search entirely (breached / hysteresis-quarantined queries) and the
        # previously deployed plan to resume from — §5.1 step 5's "merging
        # resumes from the previously deployed state".
        self.exclude_models = set(exclude_models or ())
        self.seed_plan = seed_plan
        self.pruned_candidates: list = []
        self._trainer_takes_group: Optional[bool] = None

    # -- stage 1+2: enumerate and score ---------------------------------------

    def _seed_groups(self) -> list:
        """Committed groups of the previously deployed plan, minus excluded
        members — already validated configurations, re-attempted FIRST and in
        their original commit order.  They bypass the prefilter (they have
        survived retraining once) and supersede the same-signature enumerated
        candidates (resume, don't re-litigate the previous search)."""
        if self.seed_plan is None:
            return []
        seeds = []
        for g in self.seed_plan.layer_groups():
            g = g.without_models(self.exclude_models)
            if len(g.records) >= 2 and any(len(c) >= 2 for c in g.columns()):
                seeds.append(g)
        return seeds

    def candidates(self) -> list:
        records = [r for r in self.records
                   if r.model_id not in self.exclude_models]
        groups = enumerate_groups(records)
        kept, pruned = self.scorer.prefilter(groups)
        self.pruned_candidates = pruned
        ordered = self.scorer.order(kept)
        seeds = self._seed_groups()
        if not seeds:
            return ordered
        seed_sigs = {g.signature for g in seeds}
        return seeds + [g for g in ordered if g.signature not in seed_sigs]

    # -- rollback support ------------------------------------------------------

    def _snapshot(self):
        return dict(self.store.buffers), {
            m: dict(b) for m, b in self.store.bindings.items()
        }

    def _restore(self, snap):
        self.store.buffers, self.store.bindings = snap[0], snap[1]
        self.store.bump_epoch()  # rollback rebinds: invalidate cached pytrees

    def _involved(self, group: LayerGroup) -> list:
        return [self.models[mid] for mid in sorted(group.models)
                if mid in self.models]

    def _train(self, group: LayerGroup):
        """Stage 3: joint retrain of the candidate configuration.  Trainers
        whose ``train`` accepts a ``group=`` kwarg (surrogates that judge the
        attempted configuration itself) receive it; ``MergeTrainer`` reads
        the configuration from the store bindings and does not."""
        if self._trainer_takes_group is None:
            try:
                sig = inspect.signature(self.trainer.train)
                self._trainer_takes_group = "group" in sig.parameters
            except (TypeError, ValueError):
                self._trainer_takes_group = False
        if self._trainer_takes_group:
            return self.trainer.train(self.store, self._involved(group),
                                      group=group)
        return self.trainer.train(self.store, self._involved(group))

    # -- stage 3+4: attempt, commit/rollback -----------------------------------

    def run(self) -> PlanResult:
        t0 = self.clock()
        baseline = self.store.resident_bytes()
        events: list = []
        committed_groups: list = []
        attempted = committed = discarded = 0
        cumulative_saved = 0
        best_obj = (self.objective(self.store, []) if self.objective is not None
                    else None)

        queue = self.candidates()
        qi = 0
        while qi < len(queue):
            if (self.time_budget_s is not None
                    and self.clock() - t0 > self.time_budget_s):
                break
            group = queue[qi]
            next_score = (self.scorer.score(queue[qi + 1])
                          if qi + 1 < len(queue) else 0.0)

            while True:  # AIMD retry loop on this group
                if len(group.records) < 2 or group.savings < self.min_group_bytes:
                    discarded += 1
                    break
                attempted += 1
                att0 = self.clock()
                snap = self._snapshot()
                before = self.store.resident_bytes()
                self.store.merge_group(group)
                result = self._train(group)
                if (self.attempt_budget_s is not None
                        and self.clock() - att0 > self.attempt_budget_s):
                    # attempt blew its budget: a successful retrain still
                    # commits (it's validated work), a failed one rolls back
                    # — but either way planning STOPS and ships what's done
                    self.timed_out = True

                if result.success:
                    obj = None
                    if self.objective is not None:
                        obj = self.objective(self.store,
                                             committed_groups + [group])
                        if obj < best_obj - self.objective_tolerance:
                            # retraining passed but the *deployed* quality
                            # regressed (e.g. merging broke the swap order):
                            # roll back the commit and move on.
                            self._restore(snap)
                            discarded += 1
                            break
                        best_obj = obj
                    committed += 1
                    committed_groups.append(group)
                    after = self.store.resident_bytes()
                    saved = before - after
                    cumulative_saved += saved
                    shipped = sum(
                        self.store.model_bytes(mid)
                        for mid in sorted(group.models)
                    )
                    ev = MergeEvent(
                        self.clock() - t0, group.signature, len(group.records),
                        saved, cumulative_saved, shipped, result.accuracies,
                        objective=obj,
                    )
                    events.append(ev)
                    if self.on_commit:
                        self.on_commit(ev, self.store)
                    break

                # failure: roll back weights/bindings to last successful state
                self._restore(snap)
                if self.timed_out:
                    discarded += 1
                    break
                if result.failed_models:
                    group = group.without_models(result.failed_models)
                else:
                    group = group.drop_earliest_half()
                # keep retrying only while the shrunken group still out-ranks
                # the next candidate in the scorer's order (§5.3)
                if (self.scorer.score(group) <= next_score
                        or len(group.records) < 2):
                    discarded += 1
                    break
            if self.timed_out:
                break
            qi += 1

        plan = self.store.export_plan(
            committed_groups,
            provenance=self._provenance(events, attempted, committed,
                                        discarded, baseline, best_obj),
            include_weights=self.plan_weights,
        )
        return PlanResult(
            self.store, events, attempted, committed, discarded,
            baseline, self.store.resident_bytes(),
            pruned=len(self.pruned_candidates), plan=plan,
            timed_out=self.timed_out,
        )

    def _provenance(self, events, attempted, committed, discarded,
                    baseline, best_obj) -> dict:
        prov = {
            "planner": type(self).__name__,
            "scorer": self.scorer.name,
            "warm_start": self.seed_plan is not None,
            "excluded": sorted(self.exclude_models),
            "attempted": attempted,
            "committed": committed,
            "discarded": discarded,
            "pruned": len(self.pruned_candidates),
            "replan_timed_out": self.timed_out,
            "baseline_bytes": baseline,
            "final_bytes": self.store.resident_bytes(),
            "events": [
                {"time": e.time,
                 "signature": signature_to_json(e.group_signature),
                 "n_appearances": e.n_appearances,
                 "saved_bytes": e.saved_bytes,
                 "objective": e.objective}
                for e in events
            ],
        }
        if self.objective is not None:
            prov["objective_final"] = best_obj
        return prov

"""GEMEL's contribution: model merging for memory-constrained multi-model
inference — signatures, layer groups, the ParamStore weight-unification
substrate, the pluggable staged merge planner (policy.py), joint retraining,
validation and drift tracking."""
from repro.core.groups import LayerGroup, enumerate_groups, potential_savings
from repro.core.merging import MergeResult, MergeTrainer
from repro.core.planner import IncrementalMerger
from repro.core.policy import (
    CandidateScorer,
    MemoryForwardScorer,
    MergeEvent,
    MergePlan,
    PlanResult,
    RepresentationSimilarityScorer,
    StagedPlanner,
)
from repro.core.signatures import (
    LayerRecord,
    records_from_params,
    records_from_spec,
    signature_match_fraction,
)
from repro.core.store import ParamStore
from repro.core.validation import RegisteredModel, meets_targets, validate

__all__ = [
    "CandidateScorer", "LayerGroup", "LayerRecord", "MemoryForwardScorer",
    "ParamStore", "RegisteredModel", "RepresentationSimilarityScorer",
    "IncrementalMerger", "MergeEvent", "MergePlan", "MergeResult",
    "MergeTrainer", "PlanResult", "StagedPlanner", "enumerate_groups",
    "potential_savings", "records_from_params", "records_from_spec",
    "signature_match_fraction", "meets_targets", "validate",
]

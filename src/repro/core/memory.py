"""Memory accounting (§3.1, §5.2).

* per-layer cumulative distributions (Fig 9 / power-law observation O1)
* load vs. run footprints (Table 1): run = params + activations(batch)
* workload totals and the min/50%/75% memory settings from §2.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from repro.core.signatures import LayerRecord

# Activation footprint model for the vision zoo: intermediates scale with the
# spatial resolution schedule; calibrated so Table-1 "run" columns land near
# the paper's measurements (run ≈ load + act_base * batch).  ``spec`` args
# are duck-typed descriptors (``family``/``bytes`` attrs) — core stays
# model-agnostic.
_ACT_BASE_GB = {
    "resnet": 0.11, "vgg": 0.10, "yolo": 0.17, "ssd": 0.07,
    "frcnn": 1.40, "inception": 0.04, "mobilenet": 0.03,
}


def activation_bytes(spec, batch: int) -> int:
    base = _ACT_BASE_GB.get(spec.family, 0.08)
    # sub-linear batch growth (allocator reuse), matching Table 1 ratios
    return int(base * 1e9 * (1 + 0.75 * (batch - 1)))


def load_bytes(spec) -> int:
    return spec.bytes


def run_bytes(spec, batch: int) -> int:
    return load_bytes(spec) + activation_bytes(spec, batch)


# ---------------------------------------------------------------------------
# Power-law / cumulative layer memory (Fig 9, observation O1)
# ---------------------------------------------------------------------------


def cumulative_layer_memory(records: list[LayerRecord]) -> np.ndarray:
    """Cumulative fraction of model memory, layer by layer start→end."""
    sizes = np.array([r.bytes for r in sorted(records, key=lambda r: r.position)],
                     dtype=np.float64)
    total = sizes.sum()
    return np.cumsum(sizes) / max(total, 1.0)


def heavy_hitter_stats(records: list[LayerRecord], top_frac: float = 0.15) -> dict:
    """What fraction of memory do the top ``top_frac`` heaviest layers hold,
    and where do they live in the model (0=start, 1=end)?"""
    recs = sorted(records, key=lambda r: -r.bytes)
    k = max(1, int(np.ceil(top_frac * len(recs))))
    top = recs[:k]
    total = sum(r.bytes for r in recs)
    return {
        "n_layers": len(recs),
        "top_k": k,
        "top_mem_fraction": sum(r.bytes for r in top) / max(total, 1),
        "mean_position": float(np.mean([r.position for r in top])),
    }


# ---------------------------------------------------------------------------
# Workload footprints (§2 memory settings)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadMemory:
    """min / 50% / 75% memory settings for a workload (§2)."""

    min_bytes: int  # largest single model load+run at batch 1
    max_bytes: int  # all models resident + largest activation
    framework_bytes: int = int(0.8e9)  # PyTorch fixed cost (paper §3.1)

    @property
    def mid50(self) -> int:
        return self.max_bytes // 2

    @property
    def mid75(self) -> int:
        return (3 * self.max_bytes) // 4

    def setting(self, name: str) -> int:
        return {"min": self.min_bytes, "50%": self.mid50, "75%": self.mid75}[name]


def workload_memory(specs: Iterable, batch: int = 1) -> WorkloadMemory:
    specs = list(specs)
    per_model_run = [run_bytes(s, batch) for s in specs]
    min_bytes = max(per_model_run)
    max_bytes = sum(load_bytes(s) for s in specs) + max(
        activation_bytes(s, batch) for s in specs
    )
    return WorkloadMemory(min_bytes=min_bytes, max_bytes=max_bytes)

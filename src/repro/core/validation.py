"""Accuracy vetting (§5.1 step 2 / §5.5): merged configurations ship to the
edge only after every constituent model meets its per-model accuracy target
*relative to the original (unmerged) model*."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass
class RegisteredModel:
    """One user-registered query (§5.1): a model + data + accuracy target."""

    model_id: str
    loss_fn: Callable  # (params, batch) -> scalar loss
    accuracy_fn: Callable  # (params, batch) -> scalar in [0, 1]
    train_batches: Callable  # (epoch:int) -> iterable of batches
    val_batch: Any
    accuracy_target: float = 0.95  # relative to original accuracy
    original_accuracy: Optional[float] = None  # measured before merging

    @property
    def absolute_target(self) -> float:
        base = self.original_accuracy if self.original_accuracy is not None else 1.0
        return self.accuracy_target * base


def validate(store, models: list, buffers=None) -> dict:
    """Per-model accuracy of the *current* store weights."""
    out = {}
    for m in models:
        params = (store.materialize_cached(m.model_id) if buffers is None
                  else store.materialize(m.model_id, buffers))
        out[m.model_id] = float(m.accuracy_fn(params, m.val_batch))
    return out


def meets_targets(accs: dict, models: list) -> bool:
    by_id = {m.model_id: m for m in models}
    return all(accs[mid] >= by_id[mid].absolute_target for mid in accs)

"""Architectural signatures — the paper's notion of "architecturally
identical" layers (§4.1): two layers can be merged iff their structural
identity matches (op kind + every shape hyperparameter), *excluding* weights.

Two sources of layers:

* layer-spec descriptors (duck-typed: anything with ``.layers`` entries
  carrying ``name``/``signature``/``bytes``, e.g. the vision zoo's
  ``ModelSpec``) — each entry is one layer; signature = (kind, shape).
* live parameter pytrees (any zoo family, via
  ``MergeableAdapter.records``) — each leaf is one layer; signature =
  (semantic kind derived from the path tail, shape, dtype).  Works on
  ``eval_shape`` trees too, so descriptor-scale and live records share ONE
  extraction path.

A :class:`LayerRecord` is one appearance of one layer in one model; the
grouping machinery (groups.py) clusters records by signature.  This module
is model-agnostic: it never imports a concrete family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

import numpy as np

from repro.utils.tree import flatten_paths, leaf_bytes


@dataclasses.dataclass(frozen=True)
class LayerRecord:
    model_id: str
    path: str  # addressable path within the model ("layer name")
    signature: tuple  # hashable structural identity
    bytes: int
    position: float  # 0..1 normalised position within the model (start→end)

    @property
    def key(self) -> tuple:
        return (self.model_id, self.path)


def signature_to_json(sig: Any) -> Any:
    """Signatures are nested tuples of ints/strings; JSON has no tuple, so
    encode recursively as lists and restore with :func:`signature_from_json`
    (round-trip equality is what makes serialized MergePlans comparable)."""
    if isinstance(sig, (tuple, list)):
        return [signature_to_json(s) for s in sig]
    return sig


def signature_from_json(obj: Any) -> Any:
    if isinstance(obj, list):
        return tuple(signature_from_json(o) for o in obj)
    return obj


def record_to_json(r: "LayerRecord") -> dict:
    """Appearance payload for a serialized plan (the signature is stored
    once per group, not per record)."""
    return {"model_id": r.model_id, "path": r.path,
            "bytes": r.bytes, "position": r.position}


def record_from_json(obj: dict, signature: tuple) -> "LayerRecord":
    return LayerRecord(obj["model_id"], obj["path"], signature,
                       obj["bytes"], obj["position"])


def _kind_from_path(path: str) -> str:
    """Semantic layer kind = path with numeric segments stripped, so
    ``blocks/3/attn/wq`` and ``blocks/7/attn/wq`` share a kind while
    ``blocks/3/attn/wq`` vs ``blocks/3/mlp/w_up`` do not."""
    parts = [p for p in path.split("/") if not p.isdigit()]
    return "/".join(parts)


def records_from_spec(spec: Any, model_id: Optional[str] = None) -> list[LayerRecord]:
    """One record per descriptor layer.  ``spec`` is duck-typed (``name`` +
    ``layers`` with per-layer ``name``/``signature``/``bytes``)."""
    mid = model_id or spec.name
    n = max(len(spec.layers), 1)
    return [
        LayerRecord(mid, l.name, l.signature, l.bytes, i / n)
        for i, l in enumerate(spec.layers)
    ]


def records_from_params(
    params: Any, model_id: str, include: Optional[Iterable[str]] = None
) -> list[LayerRecord]:
    """One record per param leaf.  ``include`` optionally filters paths
    (e.g. exclude embeddings from merging consideration)."""
    flat = flatten_paths(params)
    paths = sorted(flat.keys())
    n = max(len(paths), 1)
    out = []
    for i, path in enumerate(paths):
        if include is not None and not any(path.startswith(p) for p in include):
            continue
        leaf = flat[path]
        sig = (
            _kind_from_path(path),
            tuple(getattr(leaf, "shape", ())),
            str(getattr(leaf, "dtype", "float32")),
        )
        out.append(LayerRecord(model_id, path, sig, leaf_bytes(leaf), i / n))
    return out


def signature_match_fraction(a: list[LayerRecord], b: list[LayerRecord]) -> float:
    """Fig 4 metric: fraction of layers architecturally identical across a
    model pair = matched / max(len(a), len(b)), where matching is multiset
    intersection on signatures."""
    from collections import Counter

    ca = Counter(r.signature for r in a)
    cb = Counter(r.signature for r in b)
    matched = sum((ca & cb).values())
    return matched / max(len(a), len(b), 1)


# ---------------------------------------------------------------------------
# MergePlan weight-payload wire codec (DESIGN.md S3): delta vs the previously
# deployed plan + optional int8 residual quantization, for shipping plans
# over the constrained cloud->edge link (the paper's fig14 bandwidth axis).
# ---------------------------------------------------------------------------


def encode_weight_entry(arr, base=None, quantize: bool = False) -> dict:
    """One shared-buffer wire entry.  ``base`` is the value the receiving
    edge box currently holds under the same key (the previously deployed
    plan); kinds:

    * ``full``  — raw bytes (bitwise; no base, shape/dtype drift, or an
      unquantized change);
    * ``same``  — bitwise-unchanged vs base: zero payload, the edge reuses
      its resident buffer (post-apply serving stays bitwise-identical);
    * ``delta_q8`` — int8 residual ``round((arr - base)/scale)`` with a
      per-leaf amax scale (``distributed.compression`` discipline): 4x fewer
      payload bytes for float32, lossy within the drift-monitor threshold.

    Entries without a ``kind`` field decode as ``full`` (pre-S3 plans)."""
    import base64

    arr = np.asarray(arr)
    meta = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    if base is not None:
        b = np.asarray(base)
        if b.shape == arr.shape and b.dtype == arr.dtype:
            if np.array_equal(b, arr):
                return {**meta, "kind": "same"}
            if quantize and arr.dtype.kind == "f":
                from repro.distributed.compression import quantize_int8

                q, scale = quantize_int8(arr.astype(np.float32)
                                         - b.astype(np.float32))
                return {**meta, "kind": "delta_q8", "scale": scale,
                        "data": base64.b64encode(q.tobytes()).decode("ascii")}
    return {**meta, "kind": "full",
            "data": base64.b64encode(arr.tobytes()).decode("ascii")}


def decode_weight_entry(entry: dict, base=None) -> np.ndarray:
    """Reconstruct a wire entry on the edge.  Delta kinds require ``base``
    (the buffer currently deployed under the entry's key)."""
    import base64

    kind = entry.get("kind", "full")
    shape, dtype = entry["shape"], entry["dtype"]
    if kind == "full":
        buf = base64.b64decode(entry["data"])
        return np.frombuffer(buf, dtype=dtype).reshape(shape)
    if base is None:
        raise ValueError(f"wire entry kind={kind!r} needs the previously "
                         "deployed buffer as base")
    b = np.asarray(base)
    if tuple(b.shape) != tuple(shape) or str(b.dtype) != dtype:
        raise ValueError(f"delta base mismatch: base {b.shape}/{b.dtype} vs "
                         f"entry {tuple(shape)}/{dtype}")
    if kind == "same":
        return b
    if kind == "delta_q8":
        from repro.distributed.compression import dequantize_int8

        q = np.frombuffer(base64.b64decode(entry["data"]),
                          dtype=np.int8).reshape(shape)
        return (b.astype(np.float32)
                + dequantize_int8(q, entry["scale"])).astype(dtype)
    raise ValueError(f"unknown wire entry kind {kind!r}")


def entry_wire_bytes(entry: dict) -> int:
    """Decoded payload bytes an entry puts on the wire (data + scale);
    structural JSON overhead is measured by the benchmark on the serialized
    plan itself."""
    import base64

    n = len(base64.b64decode(entry["data"])) if "data" in entry else 0
    return n + (4 if "scale" in entry else 0)


def weights_wire_bytes(weights: Optional[dict]) -> int:
    return sum(entry_wire_bytes(e) for e in (weights or {}).values())

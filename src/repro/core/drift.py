"""Data-drift tracking (§5.1 steps 4-5).

Edge boxes periodically sample frames; the cloud runs the *original* models
on them and compares against the merged models' outputs.  If any query's
accuracy falls below target, edge inference reverts to the original weights
for that model and merging resumes from the previously deployed state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.store import ParamStore
from repro.core.validation import RegisteredModel


@dataclasses.dataclass
class DriftReport:
    checked: dict  # model_id -> accuracy vs original on sampled data
    breached: set  # model_ids under target
    reverted: set  # model_ids whose edge inference switched to originals


class DriftMonitor:
    def __init__(self, store: ParamStore, originals: dict, models: list):
        """originals: {model_id: original params pytree} kept cloud-side."""
        self.store = store
        self.originals = originals
        self.models = {m.model_id: m for m in models}

    def check(self, sampled_batches: dict) -> DriftReport:
        """sampled_batches: {model_id: batch of recent edge frames}."""
        checked, breached = {}, set()
        for mid, batch in sampled_batches.items():
            m = self.models[mid]
            # read-only check on the serve path's cached pytree: drift checks
            # must neither bump binding epochs nor force a re-materialisation
            merged_params = self.store.materialize_cached(mid)
            acc = float(m.accuracy_fn(merged_params, batch))
            checked[mid] = acc
            if acc < m.absolute_target:
                breached.add(mid)
        return DriftReport(checked, breached, set())

    def revert(self, report: DriftReport) -> DriftReport:
        """Rebind breached models to their original private weights; shared
        buffers survive for the remaining members."""
        from repro.utils.tree import flatten_paths

        for mid in report.breached:
            flat = flatten_paths(self.originals[mid])
            for path, leaf in flat.items():
                key = f"{mid}:{path}"
                self.store.buffers[key] = leaf
                self.store.bindings[mid][path] = key
            report.reverted.add(mid)
        self.store._gc_unreferenced()
        if report.breached:
            self.store.bump_epoch()  # reverts rebind: invalidate cached pytrees
        return report

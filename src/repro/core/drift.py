"""Data-drift tracking (§5.1 steps 4-5).

Edge boxes periodically sample frames; the cloud runs the *original* models
on them and compares against the merged models' outputs.  If any query's
accuracy falls below target, edge inference reverts to the original weights
for that model and merging resumes from the previously deployed state.

The adaptation loop that *drives* this monitor lives in
``serving/lifecycle.py`` (DESIGN.md L1): breach -> revert -> incremental
re-plan -> retrain -> hot swap.  This module contributes the two artifacts
that loop consumes:

* :meth:`DriftMonitor.revert_delta` — the binding delta a revert implies,
  the revert-side analogue of ``MergePlan.binding_deltas``;
* :class:`ResumeState` — the serializable "resume merging from the last
  deployed state" payload (deployed plan + exclusions + revert history), so
  a restarted controller or the cloud planner picks up exactly where the
  edge box left off.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Optional

from repro.core.store import ParamStore
from repro.core.validation import RegisteredModel


@dataclasses.dataclass
class DriftReport:
    checked: dict  # model_id -> accuracy vs original on sampled data
    breached: set  # model_ids under target
    reverted: set  # model_ids whose edge inference switched to originals


@dataclasses.dataclass
class ResumeState:
    """§5.1 step 5 — "merging resumes from the previously deployed state" —
    as a serializable artifact: the last deployed plan (its JSON payload),
    the models currently excluded from planning (reverted / quarantined by
    revert-storm hysteresis) and the revert timestamps that drive the
    hysteresis.  ``epoch`` records the store epoch the state was captured
    at, so a consumer can detect a stale snapshot.  ``replan_timed_out``
    records whether the deployed plan came from a re-plan the planner's
    per-attempt budget truncated (``StagedPlanner(attempt_budget_s=...)``)
    — a resuming planner should treat such a seed as incomplete rather than
    converged."""

    plan_json: Optional[str]
    excluded: tuple  # model ids, sorted
    revert_history: dict  # model_id -> [revert timestamps, planner clock]
    epoch: int
    replan_timed_out: bool = False

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({
            "plan": self.plan_json,
            "excluded": list(self.excluded),
            "revert_history": {m: list(ts) for m, ts in
                               sorted(self.revert_history.items())},
            "epoch": self.epoch,
            "replan_timed_out": self.replan_timed_out,
        }, indent=indent)

    @classmethod
    def from_json(cls, payload: str) -> "ResumeState":
        obj = json.loads(payload)
        return cls(obj["plan"], tuple(obj["excluded"]),
                   {m: list(ts) for m, ts in obj["revert_history"].items()},
                   obj["epoch"],
                   replan_timed_out=obj.get("replan_timed_out", False))

    def plan(self):
        from repro.core.policy import MergePlan

        return (MergePlan.from_json(self.plan_json)
                if self.plan_json is not None else None)


class DriftMonitor:
    def __init__(self, store: ParamStore, originals: dict, models: list):
        """originals: {model_id: original params pytree} kept cloud-side."""
        self.store = store
        self.originals = originals
        self.models = {m.model_id: m for m in models}

    def check(self, sampled_batches: dict) -> DriftReport:
        """sampled_batches: {model_id: batch of recent edge frames}."""
        checked, breached = {}, set()
        for mid, batch in sampled_batches.items():
            m = self.models[mid]
            # read-only check on the serve path's cached pytree: drift checks
            # must neither bump binding epochs nor force a re-materialisation
            merged_params = self.store.materialize_cached(mid)
            acc = float(m.accuracy_fn(merged_params, batch))
            checked[mid] = acc
            if acc < m.absolute_target:
                breached.add(mid)
        return DriftReport(checked, breached, set())

    def revert_delta(self, report: DriftReport) -> dict:
        """{(model_id, path): (current_key, private_key)} for every
        appearance a revert of the breached models rebinds — the breach's
        binding delta, mirroring ``MergePlan.binding_deltas`` on the
        planning side.  Pure query: the store is untouched."""
        from repro.utils.tree import flatten_paths

        delta = {}
        for mid in sorted(report.breached):
            for path in flatten_paths(self.originals[mid]):
                delta[(mid, path)] = (self.store.bindings[mid][path],
                                      f"{mid}:{path}")
        return delta

    def revert(self, report: DriftReport) -> DriftReport:
        """Rebind breached models to their original private weights; shared
        buffers referenced by surviving group members are untouched (only
        truly unreferenced keys are GC'd).  The rebind is staged and commits
        with ONE epoch bump, so a live engine's cached pytrees AND suffix
        banks invalidate exactly once and queued requests are served against
        the reverted bindings on the very next pass."""
        from repro.utils.tree import flatten_paths

        delta = self.revert_delta(report)  # the ONE statement of the rebind
        flats = {mid: flatten_paths(self.originals[mid])
                 for mid in report.breached}
        for (mid, path), (_old, private_key) in delta.items():
            self.store.buffers[private_key] = flats[mid][path]
            self.store.bindings[mid][path] = private_key
        for mid in report.breached:
            report.reverted.add(mid)
        self.store._gc_unreferenced()
        if report.breached:
            self.store.bump_epoch()  # reverts rebind: invalidate cached pytrees
        return report
